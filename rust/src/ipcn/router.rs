//! The unit router (paper §II-B.4, Fig 3(e)): data-packet routing plus
//! in-network computing. Seven I/O ports (4 planar, AXI pair to the PE,
//! 2 vertical TSVs), per-port FIFOs, a decoder/controller driven by the
//! NMC's command stream, the computational macros, and a scratchpad.
//!
//! The router executes exactly one [`Instruction`] per cycle in two phases
//! (matching the mesh's two-phase update): `compute()` reads its input
//! FIFOs and produces output intents; the mesh then `deliver()`s intents
//! into neighbour FIFOs, honouring backpressure.

use super::fifo::Fifo;
use super::macros::{linear_act, partial_sum, DmacBank};
use super::scratchpad::Scratchpad;
use super::Word;
use crate::isa::{Instruction, Mode, Port, PortSet};
use crate::isa::instruction::IntXfer;

/// An output intent: a word to be delivered to `ports` (broadcast when
/// more than one bit set) at the *next* cycle boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OutputIntent {
    pub ports: PortSet,
    pub word: Word,
}

/// Per-router counters for power/congestion accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterStats {
    pub active_cycles: u64,
    pub idle_cycles: u64,
    pub words_routed: u64,
    pub broadcasts: u64,
    pub psum_ops: u64,
    pub linact_ops: u64,
    pub sp_reads: u64,
    pub sp_writes: u64,
    pub pe_triggers: u64,
    pub stalls: u64,
}

/// The unit router.
#[derive(Debug)]
pub struct Router {
    /// Input FIFO per port.
    pub in_fifo: [Fifo; 7],
    pub scratchpad: Scratchpad,
    pub dmac: DmacBank,
    pub stats: RouterStats,
    /// Intent produced by `compute` this cycle, delivered by the mesh.
    pending: Vec<OutputIntent>,
}

impl Router {
    pub fn new(fifo_words: usize, scratchpad_words: usize, dmac_lanes: usize) -> Router {
        Router {
            in_fifo: std::array::from_fn(|_| Fifo::new(fifo_words)),
            scratchpad: Scratchpad::new(scratchpad_words),
            dmac: DmacBank::new(dmac_lanes),
            stats: RouterStats::default(),
            pending: Vec::with_capacity(2),
        }
    }

    pub fn fifo(&self, p: Port) -> &Fifo {
        &self.in_fifo[p as usize]
    }

    pub fn fifo_mut(&mut self, p: Port) -> &mut Fifo {
        &mut self.in_fifo[p as usize]
    }

    /// Inject a word into an input FIFO (mesh edge / PE response / test).
    pub fn inject(&mut self, p: Port, w: Word) -> bool {
        self.in_fifo[p as usize].push(w)
    }

    /// Read one word from each enabled input FIFO into the stack buffer
    /// `buf` (at most 7 ports); returns the number of words read. A fixed
    /// array keeps the steady-state compute path off the heap.
    fn read_enabled(&mut self, rd_en: PortSet, buf: &mut [Word; 7]) -> usize {
        let mut n = 0;
        for p in rd_en.iter() {
            if let Some(w) = self.in_fifo[p as usize].pop() {
                buf[n] = w;
                n += 1;
            }
        }
        n
    }

    /// Phase 1: execute `instr`, consuming input FIFOs and producing output
    /// intents. Returns true when the router did useful work this cycle.
    pub fn compute(&mut self, instr: Instruction) -> bool {
        self.pending.clear();
        for f in &mut self.in_fifo {
            f.sample();
        }
        let mut buf: [Word; 7] = [0.0; 7];
        let active = match instr.mode {
            Mode::Idle => false,
            Mode::Route => {
                let n = self.read_enabled(instr.rd_en, &mut buf);
                if n == 0 {
                    self.stats.stalls += 1;
                    false
                } else {
                    for &w in &buf[..n] {
                        self.queue_out(instr.out_en, w);
                    }
                    true
                }
            }
            Mode::PartialSum => {
                let n = self.read_enabled(instr.rd_en, &mut buf);
                if n == 0 {
                    self.stats.stalls += 1;
                    false
                } else {
                    let s = partial_sum(&buf[..n]);
                    self.stats.psum_ops += 1;
                    self.queue_out(instr.out_en, s);
                    true
                }
            }
            Mode::LinearAct => {
                // (a, b) at SP_addr and SP_addr+1; x from the first rd port.
                let n = self.read_enabled(instr.rd_en, &mut buf);
                if n == 0 {
                    self.stats.stalls += 1;
                    false
                } else {
                    let x = buf[0];
                    let a = self.scratchpad.read(instr.sp_addr as usize).unwrap_or(1.0);
                    let b = self
                        .scratchpad
                        .read(instr.sp_addr as usize + 1)
                        .unwrap_or(0.0);
                    self.stats.linact_ops += 1;
                    self.queue_out(instr.out_en, linear_act(x, a, b));
                    true
                }
            }
            Mode::Dmac => {
                // Operand pairing across enabled ports: one word is read
                // from each enabled FIFO per cycle, and consecutive ports
                // form (x, y) operand pairs — e.g. rd_en = {North, West}
                // multiplies the stream arriving from the north by the
                // stream arriving from the west (QKᵀ streams K down the
                // column while q flows along the row).
                let n = self.read_enabled(instr.rd_en, &mut buf);
                let mut pairs: [(Word, Word); 3] = [(0.0, 0.0); 3];
                let np = n / 2;
                for (i, pair) in pairs.iter_mut().enumerate().take(np) {
                    *pair = (buf[2 * i], buf[2 * i + 1]);
                }
                if np == 0 {
                    self.stats.stalls += 1;
                    false
                } else {
                    self.dmac.issue(&pairs[..np]);
                    true
                }
            }
            Mode::DmacDrain => {
                let s = self.dmac.drain();
                self.queue_out(instr.out_en, s);
                true
            }
            Mode::SpRead => {
                match self.scratchpad.read(instr.sp_addr as usize) {
                    Some(w) => {
                        self.stats.sp_reads += 1;
                        self.queue_out(instr.out_en, w);
                        true
                    }
                    None => {
                        self.stats.stalls += 1;
                        false
                    }
                }
            }
            Mode::SpWrite => {
                let n = self.read_enabled(instr.rd_en, &mut buf);
                if n == 0 {
                    self.stats.stalls += 1;
                    false
                } else {
                    for (i, w) in buf[..n].iter().enumerate() {
                        self.scratchpad.write(instr.sp_addr as usize + i, *w);
                        self.stats.sp_writes += 1;
                    }
                    true
                }
            }
            Mode::PeTrigger => {
                // Forward input words to the PE port; the mesh moves them
                // across the AXI adapter and triggers the crossbar.
                let n = self.read_enabled(instr.rd_en, &mut buf);
                if n == 0 {
                    self.stats.stalls += 1;
                    false
                } else {
                    self.stats.pe_triggers += 1;
                    for &w in &buf[..n] {
                        self.queue_out(PortSet::single(Port::Pe), w);
                    }
                    true
                }
            }
            Mode::ScuStream => {
                // Stream to the activation die through the Up TSV.
                let n = self.read_enabled(instr.rd_en, &mut buf);
                if n == 0 {
                    self.stats.stalls += 1;
                    false
                } else {
                    for &w in &buf[..n] {
                        self.queue_out(PortSet::single(Port::Up), w);
                    }
                    true
                }
            }
        };

        // Internal transfer runs in parallel with the main op (§II-B.5(iv)).
        match instr.intxfer {
            IntXfer::None => {}
            IntXfer::FifoToSp => {
                if let Some(w) = self.in_fifo[Port::Pe as usize].pop() {
                    self.scratchpad.write(instr.sp_addr as usize, w);
                    self.stats.sp_writes += 1;
                }
            }
            IntXfer::SpToFifo => {
                if let Some(w) = self.scratchpad.read(instr.sp_addr as usize) {
                    self.stats.sp_reads += 1;
                    self.queue_out(PortSet::single(Port::Pe), w);
                }
            }
            IntXfer::Swap => {
                let addr = instr.sp_addr as usize;
                if let (Some(inw), Some(old)) = (
                    self.in_fifo[Port::Pe as usize].pop(),
                    self.scratchpad.read(addr),
                ) {
                    self.scratchpad.write(addr, inw);
                    self.stats.sp_reads += 1;
                    self.stats.sp_writes += 1;
                    self.queue_out(PortSet::single(Port::Pe), old);
                }
            }
        }

        if active {
            self.stats.active_cycles += 1;
        } else {
            self.stats.idle_cycles += 1;
        }
        active
    }

    fn queue_out(&mut self, ports: PortSet, w: Word) {
        if ports.is_empty() {
            return;
        }
        self.stats.words_routed += ports.len() as u64;
        if ports.is_broadcast() {
            self.stats.broadcasts += 1;
        }
        self.pending.push(OutputIntent { ports, word: w });
    }

    /// Phase 2 accessor: append the intents produced by the last `compute`
    /// call to `sink` and clear them. Unlike a `mem::take`-style getter,
    /// this reuses both the router's pending buffer and the caller's sink,
    /// so per-cycle intent collection performs no heap allocation.
    pub fn drain_intents_into(&mut self, sink: &mut Vec<OutputIntent>) {
        sink.extend_from_slice(&self.pending);
        self.pending.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(32, 4096, 16)
    }

    fn take_intents(r: &mut Router) -> Vec<OutputIntent> {
        let mut v = Vec::new();
        r.drain_intents_into(&mut v);
        v
    }

    #[test]
    fn route_unicast_moves_word() {
        let mut r = router();
        r.inject(Port::West, 3.25);
        let instr = Instruction::new(
            PortSet::single(Port::West),
            Mode::Route,
            PortSet::single(Port::East),
        );
        assert!(r.compute(instr));
        let intents = take_intents(&mut r);
        assert_eq!(intents.len(), 1);
        assert_eq!(intents[0].word, 3.25);
        assert!(intents[0].ports.contains(Port::East));
        assert_eq!(r.stats.words_routed, 1);
    }

    #[test]
    fn route_broadcast_counts_once_per_word() {
        let mut r = router();
        r.inject(Port::Pe, 1.0);
        let instr = Instruction::new(PortSet::single(Port::Pe), Mode::Route, PortSet::ALL);
        assert!(r.compute(instr));
        let intents = take_intents(&mut r);
        assert_eq!(intents.len(), 1);
        assert_eq!(intents[0].ports.len(), 7);
        assert_eq!(r.stats.broadcasts, 1);
        assert_eq!(r.stats.words_routed, 7);
    }

    #[test]
    fn empty_fifo_stalls() {
        let mut r = router();
        let instr = Instruction::new(
            PortSet::single(Port::North),
            Mode::Route,
            PortSet::single(Port::South),
        );
        assert!(!r.compute(instr));
        assert_eq!(r.stats.stalls, 1);
        assert_eq!(r.stats.idle_cycles, 1);
    }

    #[test]
    fn partial_sum_reduces_three_ports() {
        let mut r = router();
        r.inject(Port::North, 1.0);
        r.inject(Port::South, 2.0);
        r.inject(Port::West, 4.0);
        let instr = Instruction::new(
            PortSet::of(&[Port::North, Port::South, Port::West]),
            Mode::PartialSum,
            PortSet::single(Port::East),
        );
        assert!(r.compute(instr));
        assert_eq!(take_intents(&mut r)[0].word, 7.0);
        assert_eq!(r.stats.psum_ops, 1);
    }

    #[test]
    fn linear_act_reads_coeffs_from_scratchpad() {
        let mut r = router();
        r.scratchpad.write(10, 2.0); // a
        r.scratchpad.write(11, -1.0); // b
        r.inject(Port::West, 5.0);
        let instr = Instruction::new(
            PortSet::single(Port::West),
            Mode::LinearAct,
            PortSet::single(Port::East),
        )
        .with_sp(10);
        assert!(r.compute(instr));
        assert_eq!(take_intents(&mut r)[0].word, 9.0);
    }

    #[test]
    fn dmac_accumulate_then_drain() {
        let mut r = router();
        // x-stream on North, y-stream on West: (2,3) then (4,5)
        r.inject(Port::North, 2.0);
        r.inject(Port::North, 4.0);
        r.inject(Port::West, 3.0);
        r.inject(Port::West, 5.0);
        let macd = Instruction::new(
            PortSet::of(&[Port::North, Port::West]),
            Mode::Dmac,
            PortSet::EMPTY,
        );
        assert!(r.compute(macd)); // (2, 3)
        assert!(r.compute(macd)); // (4, 5)
        let drain = Instruction::new(PortSet::EMPTY, Mode::DmacDrain, PortSet::single(Port::Pe));
        assert!(r.compute(drain));
        assert_eq!(take_intents(&mut r)[0].word, 2.0 * 3.0 + 4.0 * 5.0);
    }

    #[test]
    fn dmac_single_port_stalls() {
        // one enabled port cannot form an (x, y) pair
        let mut r = router();
        r.inject(Port::North, 1.0);
        let macd = Instruction::new(PortSet::single(Port::North), Mode::Dmac, PortSet::EMPTY);
        assert!(!r.compute(macd));
        assert_eq!(r.stats.stalls, 1);
    }

    #[test]
    fn sp_write_then_read() {
        let mut r = router();
        r.inject(Port::West, 8.5);
        let wr = Instruction::new(PortSet::single(Port::West), Mode::SpWrite, PortSet::EMPTY)
            .with_sp(100);
        assert!(r.compute(wr));
        let rd = Instruction::new(PortSet::EMPTY, Mode::SpRead, PortSet::single(Port::East))
            .with_sp(100);
        assert!(r.compute(rd));
        assert_eq!(take_intents(&mut r)[0].word, 8.5);
        assert_eq!(r.stats.sp_writes, 1);
        assert_eq!(r.stats.sp_reads, 1);
    }

    #[test]
    fn intxfer_runs_alongside_route() {
        let mut r = router();
        r.inject(Port::West, 1.0); // for the Route op
        r.inject(Port::Pe, 9.0); // for the FifoToSp transfer
        let instr = Instruction::new(
            PortSet::single(Port::West),
            Mode::Route,
            PortSet::single(Port::East),
        )
        .with_sp(5)
        .with_xfer(IntXfer::FifoToSp);
        assert!(r.compute(instr));
        assert_eq!(r.scratchpad.read(5), Some(9.0));
        assert_eq!(take_intents(&mut r).len(), 1, "route still happened");
    }

    #[test]
    fn scu_stream_goes_up() {
        let mut r = router();
        r.inject(Port::Pe, 2.5);
        let instr = Instruction::new(PortSet::single(Port::Pe), Mode::ScuStream, PortSet::EMPTY);
        assert!(r.compute(instr));
        let intents = take_intents(&mut r);
        assert!(intents[0].ports.contains(Port::Up));
    }
}
