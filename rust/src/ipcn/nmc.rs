//! Network Main Controller (paper §II-B.3): reads and decodes NPM rows to
//! drive every router in the mesh. Sub-modules per the paper:
//! (i) instruction decoder — splits a row into routing command, command
//! selection, repeat count; (ii) command crossbar — a 3-input-N-output
//! crossbar fanning {CMD1, CMD2, IDLE} out to each router by its selection
//! signal; (iii) command repeat counter.

use super::npm::Npm;
use crate::isa::{Instruction, ProgramRow};

/// The NMC's per-cycle output: one instruction per router. The NMC owns
/// one slice and refills it in place each cycle, so issuing allocates
/// nothing in steady state.
#[derive(Debug, Clone, Default)]
pub struct IssueSlice {
    pub instrs: Vec<Instruction>,
    /// Label of the originating program row (for traces).
    pub label: String,
}

/// Execution state of the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NmcState {
    /// Fetching the next row from the NPM.
    Fetch,
    /// Re-issuing the current row (repeat counter > 0).
    Repeat,
    /// Active bank exhausted; waiting for a flip.
    Drained,
}

/// The Network Main Controller.
#[derive(Debug)]
pub struct Nmc {
    n_routers: usize,
    current: Option<ProgramRow>,
    /// Command repeat counter (decrements per issued cycle).
    repeat_left: u32,
    pub state: NmcState,
    pub cycles_issued: u64,
    /// Reusable issue slice, refilled in place each cycle.
    slice: IssueSlice,
}

impl Nmc {
    pub fn new(n_routers: usize) -> Nmc {
        Nmc {
            n_routers,
            current: None,
            repeat_left: 0,
            state: NmcState::Fetch,
            cycles_issued: 0,
            slice: IssueSlice {
                instrs: Vec::with_capacity(n_routers),
                label: String::new(),
            },
        }
    }

    /// Advance one cycle: fetch/decode from the NPM as needed and produce
    /// the per-router instruction slice via the command crossbar. Returns
    /// `None` when the NPM is drained (caller decides whether to flip).
    pub fn issue(&mut self, npm: &mut Npm) -> Option<&IssueSlice> {
        if self.repeat_left == 0 {
            match npm.next_row() {
                Some(row) => {
                    self.repeat_left = row.repeat.max(1);
                    // Copy the row into the NMC-owned slot field-by-field so
                    // its Vec/String allocations are reused across fetches.
                    match &mut self.current {
                        Some(cur) => {
                            cur.cmd1 = row.cmd1;
                            cur.cmd2 = row.cmd2;
                            cur.repeat = row.repeat;
                            cur.router_cfg.clear();
                            cur.router_cfg.extend_from_slice(&row.router_cfg);
                            cur.label.clear();
                            cur.label.push_str(&row.label);
                        }
                        None => self.current = Some(row.clone()),
                    }
                    self.state = NmcState::Fetch;
                }
                None => {
                    self.current = None;
                    self.state = NmcState::Drained;
                    return None;
                }
            }
        } else {
            self.state = NmcState::Repeat;
        }

        let row = self.current.as_ref().expect("row present when issuing");
        // Command crossbar: 3 inputs (CMD1, CMD2, IDLE) × N outputs, fanned
        // into the reusable slice.
        self.slice.instrs.clear();
        for r in 0..self.n_routers {
            self.slice.instrs.push(row.instruction_for(r));
        }
        self.slice.label.clear();
        self.slice.label.push_str(&row.label);
        self.repeat_left -= 1;
        self.cycles_issued += 1;
        Some(&self.slice)
    }

    /// True when the current row still has repeats pending.
    pub fn mid_row(&self) -> bool {
        self.repeat_left > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Mode, Port, PortSet, Program, ProgramRow};

    fn one_row_program(repeat: u32) -> Program {
        let mut p = Program::new(4);
        let instr = Instruction::new(
            PortSet::single(Port::West),
            Mode::Route,
            PortSet::single(Port::East),
        );
        p.push(ProgramRow::uniform(instr, 4, repeat).with_label("row0"));
        p
    }

    #[test]
    fn issues_row_repeat_times() {
        let mut npm = Npm::new();
        npm.bootstrap(&one_row_program(3));
        let mut nmc = Nmc::new(4);
        for i in 0..3 {
            let slice = nmc.issue(&mut npm).unwrap_or_else(|| panic!("cycle {i}"));
            assert_eq!(slice.instrs.len(), 4);
            assert_eq!(slice.label, "row0");
        }
        assert!(nmc.issue(&mut npm).is_none(), "drained after 3 issues");
        assert_eq!(nmc.state, NmcState::Drained);
        assert_eq!(nmc.cycles_issued, 3);
    }

    #[test]
    fn repeat_zero_treated_as_one() {
        let mut npm = Npm::new();
        npm.bootstrap(&one_row_program(0));
        let mut nmc = Nmc::new(4);
        assert!(nmc.issue(&mut npm).is_some());
        assert!(nmc.issue(&mut npm).is_none());
    }

    #[test]
    fn crossbar_fans_out_selection() {
        let mut p = Program::new(3);
        let c1 = Instruction::new(
            PortSet::single(Port::West),
            Mode::Route,
            PortSet::single(Port::East),
        );
        let c2 = Instruction::new(PortSet::single(Port::North), Mode::Dmac, PortSet::EMPTY);
        let mut row = ProgramRow::uniform(c1, 3, 1);
        row.cmd2 = c2;
        row.router_cfg[1].sel = crate::isa::CommandSel::Cmd2;
        row.router_cfg[2].sel = crate::isa::CommandSel::Idle;
        p.push(row);
        let mut npm = Npm::new();
        npm.bootstrap(&p);
        let mut nmc = Nmc::new(3);
        let slice = nmc.issue(&mut npm).unwrap();
        assert_eq!(slice.instrs[0].mode, Mode::Route);
        assert_eq!(slice.instrs[1].mode, Mode::Dmac);
        assert_eq!(slice.instrs[2].mode, Mode::Idle);
    }

    #[test]
    fn resumes_after_bank_flip() {
        let mut npm = Npm::new();
        npm.bootstrap(&one_row_program(1));
        let mut nmc = Nmc::new(4);
        assert!(nmc.issue(&mut npm).is_some());
        assert!(nmc.issue(&mut npm).is_none());
        // co-processor refills and flips
        npm.configure_inactive(one_row_program(2).rows);
        assert!(npm.flip());
        assert!(nmc.issue(&mut npm).is_some());
        assert!(nmc.mid_row());
        assert!(nmc.issue(&mut npm).is_some());
        assert!(nmc.issue(&mut npm).is_none());
    }
}
