//! Chiplet clusters (paper §II-E, Fig 5): four adjacent compute-tile
//! chiplets grouped as a cluster; CCPG activates exactly one cluster at a
//! time, keeping only scratchpad retention alive elsewhere.

use super::tile::{ComputeTile, TileState};
use crate::config::MacroPower;

/// Aggregate state of a cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterState {
    Active,
    Sleep,
}

/// A cluster of (up to) `tiles_per_cluster` adjacent tiles.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub id: u32,
    pub tiles: Vec<ComputeTile>,
    pub state: ClusterState,
}

impl Cluster {
    pub fn new(id: u32, tiles: Vec<ComputeTile>) -> Cluster {
        assert!(!tiles.is_empty(), "cluster needs tiles");
        let mut c = Cluster {
            id,
            tiles,
            state: ClusterState::Sleep,
        };
        c.apply_state();
        c
    }

    /// Propagate the cluster state to member tiles.
    fn apply_state(&mut self) {
        let tile_state = match self.state {
            ClusterState::Active => TileState::Active,
            ClusterState::Sleep => TileState::Sleep,
        };
        for t in &mut self.tiles {
            if t.state != TileState::Off {
                t.state = tile_state;
            }
        }
    }

    pub fn wake(&mut self) {
        self.state = ClusterState::Active;
        self.apply_state();
    }

    pub fn sleep(&mut self) {
        self.state = ClusterState::Sleep;
        self.apply_state();
    }

    pub fn power_w(&self, p: &MacroPower) -> f64 {
        self.tiles.iter().map(|t| t.power_w(p)).sum()
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn cluster(n: usize) -> Cluster {
        let cfg = SystemConfig::default();
        Cluster::new(
            0,
            (0..n as u32).map(|i| ComputeTile::new(i, &cfg)).collect(),
        )
    }

    #[test]
    fn new_cluster_starts_asleep() {
        let c = cluster(4);
        assert_eq!(c.state, ClusterState::Sleep);
        assert!(c.tiles.iter().all(|t| t.state == TileState::Sleep));
    }

    #[test]
    fn wake_sleep_propagates() {
        let mut c = cluster(4);
        c.wake();
        assert!(c.tiles.iter().all(|t| t.state == TileState::Active));
        c.sleep();
        assert!(c.tiles.iter().all(|t| t.state == TileState::Sleep));
    }

    #[test]
    fn off_tiles_stay_off() {
        let mut c = cluster(4);
        c.tiles[3].state = TileState::Off;
        c.wake();
        assert_eq!(c.tiles[3].state, TileState::Off);
        assert_eq!(c.tiles[0].state, TileState::Active);
    }

    #[test]
    fn sleep_power_much_lower() {
        let mut c = cluster(4);
        c.wake();
        let p_active = c.power_w(&MacroPower::default());
        c.sleep();
        let p_sleep = c.power_w(&MacroPower::default());
        assert!(p_sleep < 0.2 * p_active, "{p_sleep} vs {p_active}");
    }

    #[test]
    #[should_panic(expected = "cluster needs tiles")]
    fn empty_cluster_panics() {
        Cluster::new(0, vec![]);
    }
}
