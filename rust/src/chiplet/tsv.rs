//! TSV allocation (paper §II-D): "The TSVs are allocated in an alternating
//! column-wise pattern within the IPCN, i.e., TSVs in odd-numbered columns
//! connect to the top die, whereas those in even-numbered columns connect
//! to the bottom die" — halving TSV density per die pair to mitigate
//! crosstalk and improve inter-die signal integrity.

use crate::chiplet::tile::Die;

/// The per-tile TSV allocation plan.
#[derive(Debug, Clone)]
pub struct TsvPlan {
    dim: usize,
    /// TSV bundle dimension per router site (Table I: 32×2).
    bundle: (usize, usize),
}

impl TsvPlan {
    pub fn new(dim: usize, bundle: (usize, usize)) -> TsvPlan {
        TsvPlan { dim, bundle }
    }

    /// Which die the vertical port of router column `col` connects to.
    /// Even columns (0-indexed) → bottom/optical; odd columns → top/
    /// activation. ("odd-numbered" in the paper counts from 1.)
    pub fn die_for_column(&self, col: usize) -> Die {
        assert!(col < self.dim, "column out of range");
        if col % 2 == 0 {
            Die::Optical
        } else {
            Die::Activation
        }
    }

    /// A router reaches the *other* die through its even/odd neighbour —
    /// one extra planar hop. Returns the column to detour through.
    pub fn detour_column(&self, col: usize, want: Die) -> usize {
        if self.die_for_column(col) == want {
            col
        } else if col + 1 < self.dim {
            col + 1
        } else {
            col - 1
        }
    }

    /// TSVs per router site.
    pub fn tsvs_per_site(&self) -> usize {
        self.bundle.0 * self.bundle.1
    }

    /// Total TSVs on the tile; the alternating pattern halves the *per-die*
    /// density relative to every-column-to-both-dies.
    pub fn total_tsvs(&self) -> usize {
        self.dim * self.dim * self.tsvs_per_site()
    }

    /// Density relief factor vs. a both-dies-everywhere allocation.
    pub fn density_relief(&self) -> f64 {
        0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alternating_column_pattern() {
        let p = TsvPlan::new(32, (32, 2));
        assert_eq!(p.die_for_column(0), Die::Optical);
        assert_eq!(p.die_for_column(1), Die::Activation);
        assert_eq!(p.die_for_column(30), Die::Optical);
        assert_eq!(p.die_for_column(31), Die::Activation);
    }

    #[test]
    fn detour_reaches_other_die_in_one_hop() {
        let p = TsvPlan::new(32, (32, 2));
        // column 0 (optical) wants the activation die → detour via col 1
        assert_eq!(p.detour_column(0, Die::Activation), 1);
        // column 1 already reaches activation
        assert_eq!(p.detour_column(1, Die::Activation), 1);
        // last column edge case
        assert_eq!(p.detour_column(31, Die::Optical), 30);
    }

    #[test]
    fn counts_match_table1() {
        let p = TsvPlan::new(32, (32, 2));
        assert_eq!(p.tsvs_per_site(), 64);
        assert_eq!(p.total_tsvs(), 32 * 32 * 64);
        assert_eq!(p.density_relief(), 0.5);
    }

    #[test]
    #[should_panic(expected = "column out of range")]
    fn oob_column_panics() {
        TsvPlan::new(4, (32, 2)).die_for_column(4);
    }
}
