//! 3D-stacked compute tiles, TSV allocation, chiplet clusters, and the
//! Chiplet Clustering + Power Gating scheme (paper §II-D, §II-E, Fig 5).

mod ccpg;
mod cluster;
mod tile;
mod tsv;

pub use ccpg::{Ccpg, CcpgStats, CcpgTimeline};
pub use cluster::{Cluster, ClusterState};
pub use tile::{ComputeTile, Die, TileState};
pub use tsv::TsvPlan;
