//! One 3D-SIC compute tile (paper §II-D, Fig 3(b)): three heterogeneous
//! dies stacked with TSVs — activation functions (top), IPCN 2D-mesh + PEs
//! (middle), optical engine (bottom).

use crate::config::{MacroArea, MacroPower, SystemConfig};

/// The three dies of a compute tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Die {
    /// Top: activation-function macros (the SCUs).
    Activation,
    /// Middle: IPCN 2D mesh + RRAM-CIM PEs.
    IpcnPe,
    /// Bottom: optical engine (laser, MRM, switches, photodetectors).
    Optical,
}

/// Power state of a tile (CCPG drives transitions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileState {
    /// Fully active: all macros powered.
    Active,
    /// Sleep: everything gated except scratchpad retention (KV cache).
    Sleep,
    /// Unused: no model layer mapped here (fully off).
    Off,
}

/// A compute-tile chiplet, as the power/area model sees it.
#[derive(Debug, Clone)]
pub struct ComputeTile {
    pub id: u32,
    pub state: TileState,
    /// Number of router-PE pairs actually carrying mapped weights.
    pub pairs_used: usize,
    /// Total router-PE pairs on the die (ipcn_dim²).
    pub pairs_total: usize,
    /// SCUs on the activation die.
    pub scu_count: usize,
}

impl ComputeTile {
    pub fn new(id: u32, cfg: &SystemConfig) -> ComputeTile {
        ComputeTile {
            id,
            state: TileState::Active,
            pairs_used: cfg.routers_per_tile(),
            pairs_total: cfg.routers_per_tile(),
            scu_count: cfg.scu_per_tile,
        }
    }

    /// Tile power under the given state (paper's CCPG power model):
    /// * Active — every used pair at full 259 µW + SCUs;
    /// * Sleep  — scratchpads of used pairs stay on (KV-cache retention),
    ///            all other macros leak at the gated fraction;
    /// * Off    — zero (rail off; RRAM keeps weights, it is non-volatile).
    pub fn power_w(&self, p: &MacroPower) -> f64 {
        match self.state {
            TileState::Active => {
                self.pairs_used as f64 * p.unit_pair_w()
                    + self.scu_count as f64 * p.softmax_w
            }
            TileState::Sleep => {
                let retained = self.pairs_used as f64 * p.scratchpad_w;
                let gated = self.pairs_used as f64 * (p.pe_w + p.router_w) * p.sleep_leak_frac
                    + self.scu_count as f64 * p.softmax_w * p.sleep_leak_frac;
                retained + gated
            }
            TileState::Off => 0.0,
        }
    }

    /// Silicon area of the IPCN+PE die (the dominant die; paper Table IV:
    /// 189.6 mm² per compute-tile chiplet).
    pub fn area_mm2(&self, a: &MacroArea) -> f64 {
        self.pairs_total as f64 * a.unit_pair_mm2() + self.scu_count as f64 * a.softmax_mm2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tile() -> ComputeTile {
        ComputeTile::new(0, &SystemConfig::default())
    }

    #[test]
    fn active_tile_power_matches_table_iv_aggregate() {
        let t = tile();
        let p = t.power_w(&MacroPower::default());
        // 1024 pairs × 259 µW + 1024 SCUs × 5.31 µW ≈ 0.2652 + 0.0054 W
        assert!((p - (1024.0 * 259e-6 + 1024.0 * 5.31e-6)).abs() < 1e-9);
        assert!(p > 0.27 && p < 0.272, "tile power ≈ 0.2706 W, got {p}");
    }

    #[test]
    fn sleep_keeps_scratchpads_only() {
        let mut t = tile();
        t.state = TileState::Sleep;
        let mp = MacroPower::default();
        let p = t.power_w(&mp);
        let retained = 1024.0 * 42e-6;
        assert!(p >= retained, "retention floor");
        assert!(p < retained * 1.2, "gated macros nearly off: {p}");
        // sleep is a large saving vs active
        let mut active = tile();
        active.state = TileState::Active;
        assert!(p < 0.2 * active.power_w(&mp), "≥80% saved per sleeping tile");
    }

    #[test]
    fn off_tile_draws_nothing() {
        let mut t = tile();
        t.state = TileState::Off;
        assert_eq!(t.power_w(&MacroPower::default()), 0.0);
    }

    #[test]
    fn partial_mapping_scales_power() {
        let mut t = tile();
        t.pairs_used = 512;
        let p = t.power_w(&MacroPower::default());
        let full = tile().power_w(&MacroPower::default());
        assert!(p < full);
    }

    #[test]
    fn tile_area_near_paper_value() {
        let t = tile();
        let area = t.area_mm2(&MacroArea::default());
        // 1024 × 0.1842 + 1024 × 0.041 ≈ 188.6 + 42 = 230.6 mm² for all
        // macros; the paper quotes 189.6 mm² per chiplet (the SCU die is
        // stacked, not adjacent — planar footprint is the IPCN+PE die).
        let planar = 1024.0 * MacroArea::default().unit_pair_mm2();
        assert!((planar - 188.6).abs() < 0.5, "IPCN+PE die ≈ paper's 189.6 mm²");
        assert!(area > planar, "3D total exceeds planar footprint");
    }
}
