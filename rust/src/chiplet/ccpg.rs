//! CCPG — Chiplet Clustering and Power Gating (paper §II-E, Fig 5).
//!
//! LLM layers execute sequentially; all chiplets holding other layers are
//! idle. CCPG groups four adjacent chiplets into a cluster, keeps exactly
//! one cluster fully active, and puts every other cluster to sleep with
//! only scratchpad retention (KV cache survives; RRAM weights are
//! non-volatile and unaffected). The paper's claim: ~80% system power
//! saved on Llama-8B, power scaling O(log n) in deployed tiles.
//!
//! Two controllers implement the scheme for the two execution models:
//!
//! * [`Ccpg`] — the sequential controller for the analytic model's
//!   layer-by-layer walk: exactly one cluster is awake; crossing a
//!   cluster boundary sleeps the old cluster and pays
//!   `wake_latency_cycles` for the new one.
//! * [`CcpgTimeline`] — per-cluster wake accounting for the
//!   pipeline-parallel serving scheduler, where tokens of different
//!   requests occupy different clusters at the same simulated instant.
//!   Each cluster tracks the cycle its last occupancy ended; a stage
//!   occupancy starting more than `idle_sleep_cycles` later pays the
//!   wake as a per-stage stall (see the worked example on
//!   [`CcpgTimeline`]).

use super::cluster::{Cluster, ClusterState};
use super::tile::ComputeTile;
use crate::config::{CcpgConfig, MacroPower, SystemConfig};
use crate::photonic::OpticalTopology;

/// Accounting for CCPG behaviour over a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct CcpgStats {
    pub wakes: u64,
    pub wake_stall_cycles: u64,
}

impl CcpgStats {
    /// Counters accumulated since an `earlier` snapshot of the same
    /// controller — the multi-tenant server brackets each stage walk with
    /// snapshots to attribute wakes to the tenant whose job paid them.
    pub fn since(&self, earlier: &CcpgStats) -> CcpgStats {
        CcpgStats {
            wakes: self.wakes - earlier.wakes,
            wake_stall_cycles: self.wake_stall_cycles - earlier.wake_stall_cycles,
        }
    }
}

/// The CCPG controller: owns all clusters and walks the active window
/// across them as execution proceeds layer-by-layer.
#[derive(Debug)]
pub struct Ccpg {
    clusters: Vec<Cluster>,
    cfg: CcpgConfig,
    active: Option<usize>,
    pub stats: CcpgStats,
}

impl Ccpg {
    /// Build clusters of adjacent tiles from the optical topology's 2×2
    /// blocks (paper Fig 5 grouping).
    pub fn new(
        n_tiles: usize,
        sys: &SystemConfig,
        cfg: CcpgConfig,
        topo: &OpticalTopology,
    ) -> Ccpg {
        let mut buckets: Vec<Vec<ComputeTile>> = vec![Vec::new(); topo.n_clusters().max(1)];
        for t in 0..n_tiles as u32 {
            buckets[topo.cluster_of(t) as usize].push(ComputeTile::new(t, sys));
        }
        let clusters: Vec<Cluster> = buckets
            .into_iter()
            .enumerate()
            .filter(|(_, ts)| !ts.is_empty())
            .map(|(i, ts)| Cluster::new(i as u32, ts))
            .collect();
        Ccpg {
            clusters,
            cfg,
            active: None,
            stats: CcpgStats::default(),
        }
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    pub fn clusters(&self) -> &[Cluster] {
        &self.clusters
    }

    /// Cluster index that holds tile `tile`.
    pub fn cluster_of_tile(&self, tile: u32) -> Option<usize> {
        self.clusters
            .iter()
            .position(|c| c.tiles.iter().any(|t| t.id == tile))
    }

    /// Make the cluster containing `tile` the (single) active cluster.
    /// Returns the wake latency paid (0 if it was already active, or if
    /// CCPG is disabled — everything is always on then).
    pub fn activate_for_tile(&mut self, tile: u32) -> u64 {
        if !self.cfg.enabled {
            return 0;
        }
        let idx = self
            .cluster_of_tile(tile)
            .expect("tile belongs to a cluster");
        if self.active == Some(idx) {
            return 0;
        }
        if let Some(prev) = self.active {
            self.clusters[prev].sleep();
        }
        self.clusters[idx].wake();
        self.active = Some(idx);
        self.stats.wakes += 1;
        self.stats.wake_stall_cycles += self.cfg.wake_latency_cycles;
        self.cfg.wake_latency_cycles
    }

    /// Instantaneous system power: with CCPG, one active cluster + sleepers;
    /// without, everything active.
    pub fn system_power_w(&self, p: &MacroPower) -> f64 {
        if !self.cfg.enabled {
            return self
                .clusters
                .iter()
                .map(|c| {
                    // disabled: treat every cluster as active
                    let mut c2 = c.clone();
                    c2.wake();
                    c2.power_w(p)
                })
                .sum();
        }
        self.clusters.iter().map(|c| c.power_w(p)).sum()
    }

    /// Fraction of tiles currently in sleep state.
    pub fn sleep_fraction(&self) -> f64 {
        let total: usize = self.clusters.iter().map(|c| c.n_tiles()).sum();
        let sleeping: usize = self
            .clusters
            .iter()
            .filter(|c| c.state == ClusterState::Sleep)
            .map(|c| c.n_tiles())
            .sum();
        if total == 0 {
            0.0
        } else {
            sleeping as f64 / total as f64
        }
    }
}

/// Per-cluster wake accounting for the **pipeline-parallel** coordinator.
///
/// The sequential [`Ccpg`] controller keeps exactly one cluster awake —
/// correct for the analytic model's layer-by-layer walk, but the
/// event-driven scheduler has tokens of *different* requests occupying
/// different pipeline stages (and therefore different clusters) at the
/// same simulated instant. `CcpgTimeline` tracks, per cluster, the last
/// cycle it was busy; a stage occupancy starting more than
/// `idle_sleep_cycles` after that pays `wake_latency_cycles` as a
/// per-stage event instead of the old flat per-pass adder.
///
/// ```
/// use picnic::chiplet::CcpgTimeline;
/// use picnic::config::CcpgConfig;
/// use picnic::photonic::OpticalTopology;
///
/// let cfg = CcpgConfig { enabled: true, ..CcpgConfig::default() };
/// let (wake, idle) = (cfg.wake_latency_cycles, cfg.idle_sleep_cycles);
/// let mut t = CcpgTimeline::new(16, cfg, &OpticalTopology::new(16));
///
/// assert_eq!(t.occupy(0, 0, 100), wake, "cold cluster pays its wake");
/// assert_eq!(t.occupy(1, 50, 100), 0, "same 2x2 cluster is still awake");
/// assert_eq!(t.occupy(15, 60, 100), wake, "other clusters wake separately");
/// // …and a cluster left idle past the sleep threshold re-pays the wake
/// let long_idle = wake + 100 + 100 + idle + 1;
/// assert_eq!(t.occupy(0, long_idle, 10), wake);
/// assert_eq!(t.stats.wakes, 3);
/// ```
#[derive(Debug, Clone)]
pub struct CcpgTimeline {
    cfg: CcpgConfig,
    /// tile → cluster index (Fig 5 2×2 grouping via the optical grid).
    cluster_of_tile: Vec<usize>,
    /// Per cluster: cycle its last occupancy ended; `None` = never woken.
    busy_until: Vec<Option<u64>>,
    /// Hard-failed tiles (fault injection): occupancies on them are
    /// no-ops — the power controller must never burn a wake on silicon
    /// that can't run the stage anyway.
    dead: Vec<bool>,
    pub stats: CcpgStats,
}

impl CcpgTimeline {
    pub fn new(n_tiles: usize, cfg: CcpgConfig, topo: &OpticalTopology) -> CcpgTimeline {
        let cluster_of_tile: Vec<usize> =
            (0..n_tiles as u32).map(|t| topo.cluster_of(t) as usize).collect();
        let n_clusters = cluster_of_tile.iter().copied().max().map_or(0, |m| m + 1);
        let n_tiles = cluster_of_tile.len();
        CcpgTimeline {
            cfg,
            cluster_of_tile,
            busy_until: vec![None; n_clusters],
            dead: vec![false; n_tiles],
            stats: CcpgStats::default(),
        }
    }

    pub fn n_clusters(&self) -> usize {
        self.busy_until.len()
    }

    /// Mark `tile` permanently failed: subsequent [`CcpgTimeline::occupy`]
    /// calls on it are no-ops (no wake, no stall, no occupancy recorded).
    pub fn kill_tile(&mut self, tile: u32) {
        if let Some(d) = self.dead.get_mut(tile as usize) {
            *d = true;
        }
    }

    /// Whether `tile` was marked dead via [`CcpgTimeline::kill_tile`].
    pub fn tile_is_dead(&self, tile: u32) -> bool {
        self.dead.get(tile as usize).copied().unwrap_or(false)
    }

    /// A pipeline stage on `tile` wants to run for `dur` cycles starting
    /// at `start`. Returns the wake stall to add before the work (0 when
    /// the cluster is still awake or CCPG is disabled) and records the
    /// occupancy. Callers must present occupancies per stage in
    /// nondecreasing `start` order (the event loop's dispatch order).
    pub fn occupy(&mut self, tile: u32, start: u64, dur: u64) -> u64 {
        if !self.cfg.enabled || self.dead[tile as usize] {
            return 0;
        }
        let c = self.cluster_of_tile[tile as usize];
        let asleep = match self.busy_until[c] {
            None => true,
            Some(end) => start.saturating_sub(end) > self.cfg.idle_sleep_cycles,
        };
        let stall = if asleep {
            self.stats.wakes += 1;
            self.stats.wake_stall_cycles += self.cfg.wake_latency_cycles;
            self.cfg.wake_latency_cycles
        } else {
            0
        };
        let end = start + stall + dur;
        match self.busy_until[c] {
            Some(prev) if end <= prev => {}
            _ => self.busy_until[c] = Some(end),
        }
        stall
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ccpg(n_tiles: usize, enabled: bool) -> Ccpg {
        let sys = SystemConfig::default();
        let topo = OpticalTopology::new(n_tiles);
        let cfg = CcpgConfig {
            enabled,
            ..CcpgConfig::default()
        };
        Ccpg::new(n_tiles, &sys, cfg, &topo)
    }

    #[test]
    fn one_cluster_active_at_a_time() {
        let mut c = ccpg(16, true);
        assert_eq!(c.n_clusters(), 4);
        c.activate_for_tile(0);
        let active: Vec<bool> = c
            .clusters()
            .iter()
            .map(|cl| cl.state == ClusterState::Active)
            .collect();
        assert_eq!(active.iter().filter(|a| **a).count(), 1);
        // moving to a tile in another cluster flips activation
        c.activate_for_tile(15);
        let active_n: usize = c
            .clusters()
            .iter()
            .filter(|cl| cl.state == ClusterState::Active)
            .count();
        assert_eq!(active_n, 1);
        assert_eq!(c.stats.wakes, 2);
    }

    #[test]
    fn reactivating_same_cluster_is_free() {
        let mut c = ccpg(16, true);
        let lat1 = c.activate_for_tile(0);
        let lat2 = c.activate_for_tile(1); // same 2×2 block
        assert!(lat1 > 0);
        assert_eq!(lat2, 0);
        assert_eq!(c.stats.wakes, 1);
    }

    #[test]
    fn power_saving_grows_with_tile_count() {
        // the paper: the larger the model, the greater the CCPG saving
        let savings: Vec<f64> = [16usize, 64, 144]
            .iter()
            .map(|&n| {
                let mut with = ccpg(n, true);
                with.activate_for_tile(0);
                let without = ccpg(n, false);
                let p = MacroPower::default();
                1.0 - with.system_power_w(&p) / without.system_power_w(&p)
            })
            .collect();
        assert!(savings[0] < savings[1] && savings[1] < savings[2], "{savings:?}");
        assert!(savings[2] > 0.75, "large systems save >75%: {savings:?}");
    }

    #[test]
    fn disabled_ccpg_draws_full_power() {
        let mut on = ccpg(64, true);
        on.activate_for_tile(0);
        let off = ccpg(64, false);
        let p = MacroPower::default();
        assert!(on.system_power_w(&p) < 0.35 * off.system_power_w(&p));
        assert_eq!(off.sleep_fraction(), 1.0, "state says sleep…");
        // …but power model ignores it when disabled
        let expect_full = 64.0
            * (1024.0 * MacroPower::default().unit_pair_w()
                + 1024.0 * MacroPower::default().softmax_w);
        assert!((off.system_power_w(&p) - expect_full).abs() / expect_full < 1e-9);
    }

    #[test]
    fn sleep_fraction_reflects_active_window() {
        let mut c = ccpg(16, true);
        c.activate_for_tile(5);
        assert!((c.sleep_fraction() - 0.75).abs() < 1e-9);
    }

    fn timeline(n_tiles: usize, enabled: bool) -> CcpgTimeline {
        let topo = OpticalTopology::new(n_tiles);
        let cfg = CcpgConfig {
            enabled,
            ..CcpgConfig::default()
        };
        CcpgTimeline::new(n_tiles, cfg, &topo)
    }

    #[test]
    fn timeline_first_touch_pays_wake() {
        let mut t = timeline(16, true);
        let wake = CcpgConfig::default().wake_latency_cycles;
        assert_eq!(t.occupy(0, 0, 100), wake, "cold cluster wakes");
        assert_eq!(t.occupy(1, 50, 100), 0, "same 2×2 block already awake");
        assert_eq!(t.stats.wakes, 1);
    }

    #[test]
    fn timeline_concurrent_clusters_each_wake_once() {
        // two tokens in different pipeline stages touch two clusters in
        // the same window: both wake, neither puts the other to sleep
        // (unlike the sequential Ccpg's single active window).
        let mut t = timeline(16, true);
        let wake = CcpgConfig::default().wake_latency_cycles;
        assert_eq!(t.occupy(0, 0, 100), wake);
        assert_eq!(t.occupy(15, 10, 100), wake, "second cluster wakes too");
        assert_eq!(t.occupy(0, 200, 100), 0, "first cluster still awake");
        assert_eq!(t.stats.wakes, 2);
    }

    #[test]
    fn timeline_idle_cluster_sleeps_and_rewakes() {
        let mut t = timeline(16, true);
        let cfg = CcpgConfig::default();
        t.occupy(0, 0, 100); // busy until wake+100
        let idle_past = cfg.wake_latency_cycles + 100 + cfg.idle_sleep_cycles + 1;
        assert_eq!(
            t.occupy(0, idle_past, 10),
            cfg.wake_latency_cycles,
            "idle past the sleep threshold → wake again"
        );
        assert_eq!(t.stats.wakes, 2);
        assert_eq!(t.stats.wake_stall_cycles, 2 * cfg.wake_latency_cycles);
    }

    #[test]
    fn timeline_dead_tile_never_wakes() {
        let mut t = timeline(16, true);
        let wake = CcpgConfig::default().wake_latency_cycles;
        t.kill_tile(0);
        assert!(t.tile_is_dead(0));
        assert_eq!(t.occupy(0, 0, 100), 0, "dead silicon never wakes");
        assert_eq!(t.stats.wakes, 0);
        // a live neighbour in the same cluster still pays its own wake —
        // the kill removed the tile, not the cluster
        assert_eq!(t.occupy(1, 0, 100), wake);
        assert_eq!(t.stats.wakes, 1);
    }

    #[test]
    fn timeline_disabled_is_free() {
        let mut t = timeline(16, false);
        assert_eq!(t.occupy(0, 0, 100), 0);
        assert_eq!(t.occupy(9, 1_000_000, 1), 0);
        assert_eq!(t.stats.wakes, 0);
    }

    #[test]
    fn stats_since_snapshot_subtracts() {
        let mut t = timeline(16, true);
        let wake = CcpgConfig::default().wake_latency_cycles;
        t.occupy(0, 0, 100);
        let snap = t.stats;
        t.occupy(15, 10, 100); // second cluster wakes inside the window
        let d = t.stats.since(&snap);
        assert_eq!(d.wakes, 1);
        assert_eq!(d.wake_stall_cycles, wake);
    }

    #[test]
    fn wake_latency_accumulates() {
        let mut c = ccpg(16, true);
        c.activate_for_tile(0);
        c.activate_for_tile(15);
        c.activate_for_tile(0);
        assert_eq!(c.stats.wakes, 3);
        assert_eq!(c.stats.wake_stall_cycles, 3 * CcpgConfig::default().wake_latency_cycles);
    }
}
