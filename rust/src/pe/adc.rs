//! ADC model with calibrated full-scale (paper §II-A: "it calibrates the
//! macro to fully utilize the ADC input swing, thereby minimizing
//! discretization errors. Offsets identified during calibration are stored
//! for subsequent compensation during inference.").
//!
//! The transfer function matches `kernels/smac.py::_smac_kernel` exactly:
//! round(x / lsb) clipped to ±(2^(bits-1)-1), then re-scaled by lsb, with a
//! stored per-column offset subtracted before conversion.

/// One ADC channel bank (one per crossbar column in the macro; modeled as a
/// vectorized converter over all columns).
#[derive(Debug, Clone)]
pub struct Adc {
    bits: u32,
    /// Per-column full-scale (max |input|) from calibration.
    full_scale: Vec<f32>,
    /// Per-column offsets stored at calibration, compensated at inference.
    offset: Vec<f32>,
    conversions: u64,
}

impl Adc {
    pub fn new(bits: u32, cols: usize) -> Adc {
        assert!((4..=16).contains(&bits), "ADC resolution out of range");
        Adc {
            bits,
            full_scale: vec![1.0; cols],
            offset: vec![0.0; cols],
            conversions: 0,
        }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    pub fn cols(&self) -> usize {
        self.full_scale.len()
    }

    pub fn conversions(&self) -> u64 {
        self.conversions
    }

    pub fn max_code(&self) -> f32 {
        (1i64 << (self.bits - 1)) as f32 - 1.0
    }

    /// Install calibration results.
    pub fn calibrate(&mut self, full_scale: Vec<f32>, offset: Vec<f32>) {
        assert_eq!(full_scale.len(), self.full_scale.len());
        assert_eq!(offset.len(), self.offset.len());
        assert!(
            full_scale.iter().all(|f| *f > 0.0),
            "full-scale must be positive"
        );
        self.full_scale = full_scale;
        self.offset = offset;
    }

    pub fn full_scale(&self) -> &[f32] {
        &self.full_scale
    }

    /// Convert analog column sums in place: offset-compensate, quantize to
    /// the calibrated swing, reconstruct.
    pub fn convert(&mut self, columns: &mut [f32]) {
        assert_eq!(columns.len(), self.full_scale.len());
        let qmax = self.max_code();
        for ((x, &fs), &off) in columns
            .iter_mut()
            .zip(self.full_scale.iter())
            .zip(self.offset.iter())
        {
            let lsb = fs / qmax;
            let code = ((*x - off) / lsb).round().clamp(-qmax, qmax);
            *x = code * lsb;
        }
        self.conversions += 1;
    }

    /// Worst-case quantization step for column `c` (for error-bound tests).
    pub fn lsb(&self, c: usize) -> f32 {
        self.full_scale[c] / self.max_code()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantizes_to_lsb_grid() {
        let mut adc = Adc::new(8, 2);
        adc.calibrate(vec![127.0, 254.0], vec![0.0, 0.0]);
        let mut cols = vec![33.3, 100.2];
        adc.convert(&mut cols);
        assert_eq!(cols[0], 33.0); // lsb = 1.0
        assert_eq!(cols[1], 100.0); // lsb = 2.0
        assert_eq!(adc.conversions(), 1);
    }

    #[test]
    fn clips_beyond_full_scale() {
        let mut adc = Adc::new(8, 1);
        adc.calibrate(vec![100.0], vec![0.0]);
        let mut cols = vec![250.0];
        adc.convert(&mut cols);
        assert!((cols[0] - 100.0).abs() < 1.0, "clipped to swing: {}", cols[0]);
    }

    #[test]
    fn offset_compensation() {
        let mut adc = Adc::new(12, 1);
        adc.calibrate(vec![100.0], vec![10.0]);
        let mut cols = vec![60.0]; // true signal 50 + offset 10
        adc.convert(&mut cols);
        assert!((cols[0] - 50.0).abs() < adc.lsb(0));
    }

    #[test]
    fn error_bounded_by_half_lsb_inside_swing() {
        let mut adc = Adc::new(10, 1);
        adc.calibrate(vec![512.0], vec![0.0]);
        for v in [-500.0f32, -77.7, 0.4, 123.456, 511.0] {
            let mut cols = vec![v];
            adc.convert(&mut cols);
            assert!(
                (cols[0] - v).abs() <= adc.lsb(0) / 2.0 + 1e-4,
                "v={v} out={}",
                cols[0]
            );
        }
    }

    #[test]
    #[should_panic(expected = "full-scale must be positive")]
    fn zero_full_scale_rejected() {
        Adc::new(8, 1).calibrate(vec![0.0], vec![0.0]);
    }
}
