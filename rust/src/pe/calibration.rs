//! Feedback-loop calibration (paper §II-A): during initialization the macro
//! runs a calibration set through the crossbar, measures the analog column
//! sums, and sets the per-column ADC full-scale so the input swing is fully
//! used; residual offsets are stored for inference-time compensation.
//!
//! Mirrors `kernels/smac.py::calibrate_full_scale`.

use super::rram::RramArray;

/// Result of one calibration pass.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub full_scale: Vec<f32>,
    pub offset: Vec<f32>,
}

impl Calibration {
    /// Run the calibration loop: for every input vector in `cal_set`
    /// (integer DAC codes, each of length `array.rows()`), record the max
    /// |column sum| as the full-scale, floored at 1.0 (an empty column must
    /// not produce a zero swing).
    ///
    /// The offset term models the sense-amp systematic error: we measure it
    /// as the column response to the all-zero vector (which an ideal array
    /// answers with exactly 0).
    pub fn run(array: &RramArray, cal_set: &[Vec<i32>]) -> Calibration {
        let cols = array.cols();
        let mut full_scale = vec![1.0f32; cols];
        let mut buf = vec![0.0f32; cols];
        for input in cal_set {
            array.column_mac(input, &mut buf);
            for (fs, &v) in full_scale.iter_mut().zip(buf.iter()) {
                *fs = fs.max(v.abs());
            }
        }
        // Offset probe: all-zero input.
        let zero = vec![0i32; array.rows()];
        array.column_mac(&zero, &mut buf);
        Calibration {
            full_scale,
            offset: buf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn array_2x3() -> RramArray {
        let mut a = RramArray::new(2, 3, 256);
        a.program(&[10, -20, 30, 5, 5, -5]);
        a
    }

    #[test]
    fn full_scale_tracks_max_abs_sum() {
        let a = array_2x3();
        let cal = Calibration::run(&a, &[vec![1, 1], vec![-2, 1]]);
        // col sums: [15, -15, 25] and [-15, 45, -65]
        assert_eq!(cal.full_scale, vec![15.0, 45.0, 65.0]);
    }

    #[test]
    fn full_scale_floored_at_one() {
        let mut a = RramArray::new(2, 2, 256);
        a.program(&[0, 0, 0, 0]);
        let cal = Calibration::run(&a, &[vec![1, 1]]);
        assert_eq!(cal.full_scale, vec![1.0, 1.0]);
    }

    #[test]
    fn ideal_array_has_zero_offset() {
        let a = array_2x3();
        let cal = Calibration::run(&a, &[vec![1, 0]]);
        assert_eq!(cal.offset, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn empty_cal_set_gives_unit_swing() {
        let a = array_2x3();
        let cal = Calibration::run(&a, &[]);
        assert_eq!(cal.full_scale, vec![1.0; 3]);
    }
}
