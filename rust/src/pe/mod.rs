//! The processing element (paper §II-A): a 256×256 non-volatile RRAM
//! compute-in-memory macro performing static-weight MAC (SMAC) in the
//! analog domain, with DAC-quantized inputs, voltage-mode sensing, and an
//! ADC whose full-scale is set by a feedback-loop calibration pass.
//!
//! The numerics here mirror `python/compile/kernels/smac.py` /
//! `kernels/ref.py` exactly — the integration tests hold this module to the
//! AOT-compiled oracle's outputs.

mod adc;
mod calibration;
mod crossbar;
mod rram;

pub use adc::Adc;
pub use calibration::Calibration;
pub use crossbar::{Crossbar, QuantSpec};
pub use rram::{RramArray, RramCell};
