//! RRAM cell and array model.
//!
//! Each cell stores one weight as a conductance level (paper: "Each unit of
//! RRAM cell stores a unit weight/parameter of the neural networks as the
//! resistance state"). Programming is one-shot per model (non-volatile);
//! an optional Gaussian conductance-relaxation term models the Nature'22
//! macro's dominant non-ideality (the paper handles it with noise-resilient
//! training + the calibration loop; we expose it so accuracy-vs-noise
//! ablations can run).

use crate::util::pool::{self, Pool};
use crate::util::Rng;

/// MAC-slot count (`rows × cols`) below which [`RramArray::column_mac_with`]
/// stays sequential: a `pe/smac_256x256`-scale call (64K slots, ~tens of µs)
/// would lose more to scoped-thread spawn than it gains, while a
/// 2048×2048 call (4M slots) amortizes it easily.
const PAR_MAC_MIN: usize = 1 << 20;

/// Fixed accumulation width of the inner kernel (see `mac_columns`).
const LANES: usize = 8;

/// A programmed RRAM cell: signed conductance code in [-(L/2-1), L/2-1].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RramCell {
    pub code: i16,
}

/// A rows×cols array of programmed cells plus programming bookkeeping.
#[derive(Debug, Clone)]
pub struct RramArray {
    rows: usize,
    cols: usize,
    /// Row-major conductance codes (f32 to allow relaxation noise).
    g: Vec<f32>,
    /// Write passes performed (the paper's point: programmed *once*).
    program_count: u64,
    levels: u16,
}

impl RramArray {
    pub fn new(rows: usize, cols: usize, levels: u16) -> RramArray {
        assert!(levels >= 4, "need at least 2 bits of conductance levels");
        RramArray {
            rows,
            cols,
            g: vec![0.0; rows * cols],
            program_count: 0,
            levels,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn levels(&self) -> u16 {
        self.levels
    }

    pub fn program_count(&self) -> u64 {
        self.program_count
    }

    /// Program the array with signed integer codes (row-major, rows×cols).
    /// Codes outside the level range are clipped — matching the quantizer
    /// in `kernels/ref.py::quantize_weights`.
    pub fn program(&mut self, codes: &[i32]) {
        assert_eq!(codes.len(), self.rows * self.cols, "code matrix shape");
        let qmax = (self.levels / 2 - 1) as i32;
        for (slot, &c) in self.g.iter_mut().zip(codes.iter()) {
            *slot = c.clamp(-qmax, qmax) as f32;
        }
        self.program_count += 1;
    }

    /// Apply conductance-relaxation noise: g ← g + N(0, σ·qmax). One-shot,
    /// like the physical relaxation after programming. Deterministic per
    /// seed (util::Rng is a seeded SplitMix64).
    pub fn relax(&mut self, sigma_frac: f64, seed: u64) {
        let mut rng = Rng::seed_from_u64(seed);
        let qmax = (self.levels / 2 - 1) as f64;
        for g in &mut self.g {
            *g += (rng.gaussian() * sigma_frac * qmax) as f32;
        }
    }

    /// Conductance code at (r, c).
    pub fn g(&self, r: usize, c: usize) -> f32 {
        self.g[r * self.cols + c]
    }

    /// Analog column sums for one input vector of integer DAC codes:
    /// out[c] = Σ_r in[r] · g[r][c]  (bitline current accumulation). The
    /// input stream stays in dense integer codes straight off the DAC;
    /// zero codes skip their wordline row entirely.
    ///
    /// The accumulation runs in fixed-width `LANES` chunks via
    /// `chunks_exact`, which eliminates bounds checks and gives LLVM a
    /// constant-trip-count inner loop to autovectorize (the ROADMAP
    /// follow-up from the PR-2 integer-code streaming change); the
    /// sub-`LANES` column remainder is handled by a scalar tail.
    pub fn column_mac(&self, input: &[i32], out: &mut [f32]) {
        self.column_mac_with(pool::global(), input, out);
    }

    /// [`RramArray::column_mac`] with an explicit worker [`Pool`].
    ///
    /// Parallelism is over **column blocks** (bitline groups), not row
    /// blocks: each worker owns a disjoint `out[c0..c1]` slice and walks
    /// all rows in the same order the sequential kernel does, so every
    /// column's f32 accumulation order — and therefore every output bit —
    /// is identical at any thread count. (A row-block split would need
    /// per-worker partial sums combined in a reduction, and f32 addition
    /// is not associative: the merged sums would differ from the
    /// sequential ones in the last ulp. Column blocks need zero scratch
    /// and zero reduction.) Blocks are `LANES`-aligned so each worker
    /// runs the same `chunks_exact` inner kernel.
    ///
    /// Calls below [`PAR_MAC_MIN`] MAC slots (or with a 1-thread pool)
    /// take the sequential path: no scope, no spawn, no allocation.
    pub fn column_mac_with(&self, pool: Pool, input: &[i32], out: &mut [f32]) {
        assert_eq!(input.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        if pool.threads() == 1 || self.rows * self.cols < PAR_MAC_MIN || self.cols < 2 * LANES {
            self.mac_columns(input, 0, out);
            return;
        }
        let chunk = self.cols.div_ceil(pool.threads()).next_multiple_of(LANES);
        pool.par_chunks_mut(out, chunk, |ci, block| {
            self.mac_columns(input, ci * chunk, block);
        });
    }

    /// The sequential inner kernel on the column window starting at `c0`,
    /// `out.len()` columns wide: fixed-width `LANES` chunks via
    /// `chunks_exact` (no bounds checks, constant-trip-count loop for
    /// autovectorization) plus a scalar tail. Column windows are
    /// independent — the per-column arithmetic never crosses a window
    /// boundary, which is what makes the block split above exact.
    fn mac_columns(&self, input: &[i32], c0: usize, out: &mut [f32]) {
        let width = out.len();
        let body = width - width % LANES;
        out.iter_mut().for_each(|o| *o = 0.0);
        for (r, &code) in input.iter().enumerate() {
            if code == 0 {
                continue;
            }
            let x = code as f32;
            let start = r * self.cols + c0;
            let row = &self.g[start..start + width];
            let (row_body, row_tail) = row.split_at(body);
            let (out_body, out_tail) = out.split_at_mut(body);
            for (o, g) in out_body
                .chunks_exact_mut(LANES)
                .zip(row_body.chunks_exact(LANES))
            {
                for i in 0..LANES {
                    o[i] += x * g[i];
                }
            }
            for (o, &g) in out_tail.iter_mut().zip(row_tail.iter()) {
                *o += x * g;
            }
        }
    }

    /// Weights survive power cycling (non-volatility) — CCPG tests assert
    /// this instead of re-programming after wake.
    pub fn non_volatile(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_clips_to_levels() {
        let mut a = RramArray::new(2, 2, 256);
        a.program(&[300, -300, 5, 0]);
        assert_eq!(a.g(0, 0), 127.0);
        assert_eq!(a.g(0, 1), -127.0);
        assert_eq!(a.g(1, 0), 5.0);
        assert_eq!(a.program_count(), 1);
    }

    #[test]
    fn column_mac_matches_manual() {
        let mut a = RramArray::new(2, 3, 256);
        a.program(&[1, 2, 3, 4, 5, 6]);
        let mut out = vec![0.0; 3];
        a.column_mac(&[2, 10], &mut out);
        assert_eq!(out, vec![2.0 + 40.0, 4.0 + 50.0, 6.0 + 60.0]);
    }

    #[test]
    fn relax_is_reproducible_and_small() {
        let mut a = RramArray::new(8, 8, 256);
        a.program(&[100; 64]);
        let mut b = a.clone();
        a.relax(0.01, 42);
        b.relax(0.01, 42);
        for r in 0..8 {
            for c in 0..8 {
                assert_eq!(a.g(r, c), b.g(r, c), "same seed, same noise");
                assert!((a.g(r, c) - 100.0).abs() < 10.0, "noise is ~1% of qmax");
            }
        }
    }

    #[test]
    fn column_mac_chunked_body_and_tail_agree() {
        // cols = 19: two full 8-lane chunks + a 3-column scalar tail —
        // result must equal the straightforward dot product on every col.
        let (rows, cols) = (5usize, 19usize);
        let mut a = RramArray::new(rows, cols, 256);
        let codes: Vec<i32> = (0..rows * cols).map(|i| (i as i32 % 13) - 6).collect();
        a.program(&codes);
        let input: Vec<i32> = (0..rows as i32).map(|r| r - 2).collect();
        let mut out = vec![0.0f32; cols];
        a.column_mac(&input, &mut out);
        for c in 0..cols {
            let want: f32 = (0..rows).map(|r| input[r] as f32 * a.g(r, c)).sum();
            assert_eq!(out[c], want, "col {c}");
        }
    }

    #[test]
    fn column_mac_parallel_is_bit_identical() {
        // 64×16397 = ~1.05M MAC slots: above PAR_MAC_MIN, with a ragged
        // column count so the last worker block is short and ends in a
        // scalar tail. Every thread count must produce the exact bytes
        // of the sequential kernel.
        let (rows, cols) = (64usize, 16_397usize);
        assert!(rows * cols >= super::PAR_MAC_MIN);
        let mut a = RramArray::new(rows, cols, 256);
        let codes: Vec<i32> = (0..rows * cols).map(|i| (i as i32 % 251) - 125).collect();
        a.program(&codes);
        let input: Vec<i32> = (0..rows as i32).map(|r| (r % 17) - 8).collect();
        let mut seq = vec![0.0f32; cols];
        a.column_mac_with(Pool::sequential(), &input, &mut seq);
        for threads in [2usize, 4, 8] {
            let mut par = vec![0.0f32; cols];
            a.column_mac_with(Pool::new(threads), &input, &mut par);
            for c in 0..cols {
                assert_eq!(
                    seq[c].to_bits(),
                    par[c].to_bits(),
                    "col {c} at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn zero_input_skips_work() {
        let mut a = RramArray::new(4, 4, 256);
        a.program(&[7; 16]);
        let mut out = vec![9.0; 4];
        a.column_mac(&[0; 4], &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "code matrix shape")]
    fn wrong_shape_panics() {
        RramArray::new(2, 2, 256).program(&[1, 2, 3]);
    }
}
