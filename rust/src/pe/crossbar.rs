//! The complete PE crossbar: DAC-quantized inputs → RRAM column MAC →
//! calibrated ADC → dequantized outputs. The end-to-end transfer function
//! is held to `python/compile/kernels/ref.py::smac` by the integration
//! tests (see rust/tests/test_oracle.rs).

use super::adc::Adc;
use super::calibration::Calibration;
use super::rram::RramArray;
use crate::util::pool::{self, Pool};

/// Input length below which `quantize_into_with` stays sequential — the
/// maxabs scan + code write on a few thousand elements is far cheaper
/// than a scoped-thread spawn.
const PAR_QUANT_MIN: usize = 1 << 15;

/// Quantization parameters for one programmed crossbar.
#[derive(Debug, Clone)]
pub struct QuantSpec {
    /// Conductance levels (256 → int8-like codes).
    pub w_levels: u16,
    /// DAC input bits.
    pub x_bits: u32,
    /// ADC output bits.
    pub adc_bits: u32,
}

impl Default for QuantSpec {
    fn default() -> Self {
        QuantSpec {
            w_levels: 256,
            x_bits: 8,
            adc_bits: 12,
        }
    }
}

/// A programmed, calibrated crossbar holding a weight tile W[rows×cols].
#[derive(Debug, Clone)]
pub struct Crossbar {
    array: RramArray,
    adc: Adc,
    spec: QuantSpec,
    /// Per-column weight scale from programming-time quantization.
    w_scale: Vec<f32>,
    /// Persistent DAC-code scratch so a SMAC allocates nothing.
    code_buf: Vec<i32>,
    /// SMAC operations performed (power accounting).
    smacs: u64,
    calibrated: bool,
}

/// Symmetric per-vector input quantization (ref.py::quantize_inputs):
/// scale = max|x| / (2^(bits-1)-1), codes = round(x/scale) clamped. Free
/// function so callers can pass a scratch buffer that lives inside the
/// same struct as the spec.
fn quantize_into(x_bits: u32, x: &[f32], codes: &mut Vec<i32>) -> f32 {
    let qmax = (1i64 << (x_bits - 1)) as f32 - 1.0;
    let maxabs = x.iter().fold(1e-8f32, |m, v| m.max(v.abs()));
    let scale = maxabs / qmax;
    codes.clear();
    codes.extend(x.iter().map(|v| (v / scale).round().clamp(-qmax, qmax) as i32));
    scale
}

/// `quantize_into` with an explicit worker [`Pool`]: the maxabs scan folds
/// per-worker chunk maxima (f32 `max` is exactly associative and
/// commutative on the non-NaN inputs we feed it, and the `1e-8` floor is
/// idempotent under `max` — so the chunked fold is bit-identical to the
/// sequential one), and the code write is a disjoint `par_chunks_mut`.
/// Below [`PAR_QUANT_MIN`] elements, or on a 1-thread pool, this is the
/// sequential function unchanged.
fn quantize_into_with(pool: Pool, x_bits: u32, x: &[f32], codes: &mut Vec<i32>) -> f32 {
    if pool.threads() == 1 || x.len() < PAR_QUANT_MIN {
        return quantize_into(x_bits, x, codes);
    }
    let qmax = (1i64 << (x_bits - 1)) as f32 - 1.0;
    let chunk = x.len().div_ceil(pool.threads());
    let maxabs = pool
        .par_map_index(x.len().div_ceil(chunk), |i| {
            let lo = i * chunk;
            let hi = (lo + chunk).min(x.len());
            x[lo..hi].iter().fold(1e-8f32, |m, v| m.max(v.abs()))
        })
        .into_iter()
        .fold(1e-8f32, f32::max);
    let scale = maxabs / qmax;
    codes.clear();
    codes.resize(x.len(), 0);
    pool.par_chunks_mut(codes, chunk, |ci, block| {
        let base = ci * chunk;
        for (c, &v) in block.iter_mut().zip(x[base..].iter()) {
            *c = (v / scale).round().clamp(-qmax, qmax) as i32;
        }
    });
    scale
}

impl Crossbar {
    /// Program a float weight tile (row-major, rows×cols) into the array,
    /// using per-column symmetric quantization (ref.py::quantize_weights).
    pub fn program(weights: &[f32], rows: usize, cols: usize, spec: QuantSpec) -> Crossbar {
        assert_eq!(weights.len(), rows * cols, "weight tile shape");
        let qmax = (spec.w_levels / 2 - 1) as f32;
        let mut w_scale = vec![1e-8f32; cols];
        for r in 0..rows {
            for c in 0..cols {
                w_scale[c] = w_scale[c].max(weights[r * cols + c].abs());
            }
        }
        for s in &mut w_scale {
            *s /= qmax;
        }
        let codes: Vec<i32> = (0..rows * cols)
            .map(|i| {
                let c = i % cols;
                (weights[i] / w_scale[c]).round().clamp(-qmax, qmax) as i32
            })
            .collect();
        let mut array = RramArray::new(rows, cols, spec.w_levels);
        array.program(&codes);
        let adc = Adc::new(spec.adc_bits, cols);
        Crossbar {
            array,
            adc,
            spec,
            w_scale,
            code_buf: Vec::with_capacity(rows),
            smacs: 0,
            calibrated: false,
        }
    }

    pub fn rows(&self) -> usize {
        self.array.rows()
    }

    pub fn cols(&self) -> usize {
        self.array.cols()
    }

    pub fn smacs(&self) -> u64 {
        self.smacs
    }

    pub fn is_calibrated(&self) -> bool {
        self.calibrated
    }

    pub fn array_mut(&mut self) -> &mut RramArray {
        &mut self.array
    }

    /// DAC quantization of one float input vector → (codes, scale).
    /// Per-vector symmetric, matching ref.py::quantize_inputs. Codes are
    /// integer DAC levels, consumed directly by `RramArray::column_mac`.
    pub fn dac_quantize(&self, x: &[f32]) -> (Vec<i32>, f32) {
        let mut codes = Vec::with_capacity(x.len());
        let scale = self.dac_quantize_into(x, &mut codes);
        (codes, scale)
    }

    /// Allocation-free DAC quantization into a caller-owned buffer;
    /// returns the per-vector scale.
    pub fn dac_quantize_into(&self, x: &[f32], codes: &mut Vec<i32>) -> f32 {
        quantize_into(self.spec.x_bits, x, codes)
    }

    /// Feedback-loop calibration with a set of float calibration vectors.
    pub fn calibrate(&mut self, cal_set: &[Vec<f32>]) {
        let dac_set: Vec<Vec<i32>> = cal_set
            .iter()
            .map(|x| self.dac_quantize(x).0)
            .collect();
        let cal = Calibration::run(&self.array, &dac_set);
        self.adc.calibrate(cal.full_scale, cal.offset);
        self.calibrated = true;
    }

    /// One SMAC into a caller-owned output buffer:
    /// y[cols] = ADC(x_codes · G) · x_scale · w_scale. Uses the persistent
    /// DAC-code scratch, so the steady-state path performs no allocation
    /// once `out` has reached `cols()` capacity.
    pub fn smac_into(&mut self, x: &[f32], out: &mut Vec<f32>) {
        self.smac_into_with(pool::global(), x, out);
    }

    /// [`Crossbar::smac_into`] with an explicit worker [`Pool`], threaded
    /// through both parallelizable phases: the DAC quantize
    /// (`quantize_into_with`) and the column MAC
    /// ([`RramArray::column_mac_with`]). The ADC convert and per-column
    /// dequant scale stay sequential — they are O(cols) and far below any
    /// useful spawn threshold. Byte-identical at any thread count; the
    /// 1-thread pool path allocates nothing in steady state.
    pub fn smac_into_with(&mut self, pool: Pool, x: &[f32], out: &mut Vec<f32>) {
        assert_eq!(x.len(), self.rows(), "input length = crossbar rows");
        let x_scale = quantize_into_with(pool, self.spec.x_bits, x, &mut self.code_buf);
        out.clear();
        out.resize(self.array.cols(), 0.0);
        self.array.column_mac_with(pool, &self.code_buf, out);
        self.adc.convert(out);
        for (v, s) in out.iter_mut().zip(self.w_scale.iter()) {
            *v *= x_scale * s;
        }
        self.smacs += 1;
    }

    /// One SMAC: convenience wrapper over [`Crossbar::smac_into`].
    pub fn smac(&mut self, x: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.cols());
        self.smac_into(x, &mut out);
        out
    }

    /// Float reference y = xᵀW for error-bound tests.
    pub fn smac_float_ref(weights: &[f32], rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; cols];
        for r in 0..rows {
            for c in 0..cols {
                y[c] += x[r] * weights[r * cols + c];
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_tile(rows: usize, cols: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..rows * cols).map(|_| rng.sym_f32(scale)).collect()
    }

    #[test]
    fn smac_tracks_float_within_quant_error() {
        let (rows, cols) = (64, 32);
        let w = random_tile(rows, cols, 1, 0.05);
        let mut xb = Crossbar::program(&w, rows, cols, QuantSpec::default());
        let x = random_tile(rows, 1, 7, 1.0);
        // feedback-loop calibration runs on representative inference data
        // (paper §II-A initialization); include the eval distribution so
        // the ADC swing covers it.
        let mut cal: Vec<Vec<f32>> = (0..8)
            .map(|i| random_tile(rows, 1, 100 + i, 1.0))
            .collect();
        cal.push(x.clone());
        xb.calibrate(&cal);
        let y = xb.smac(&x);
        let want = Crossbar::smac_float_ref(&w, rows, cols, &x);
        let (mut err2, mut ref2) = (0.0f64, 0.0f64);
        for (a, b) in y.iter().zip(want.iter()) {
            err2 += ((a - b) as f64).powi(2);
            ref2 += (*b as f64).powi(2);
        }
        let rel = (err2 / ref2.max(1e-12)).sqrt();
        assert!(rel < 0.05, "rel err {rel}");
    }

    #[test]
    fn error_shrinks_with_adc_bits() {
        let (rows, cols) = (64, 32);
        let w = random_tile(rows, cols, 2, 0.05);
        let x = random_tile(rows, 1, 3, 1.0);
        let want = Crossbar::smac_float_ref(&w, rows, cols, &x);
        let mut errs = Vec::new();
        for bits in [6, 8, 12] {
            let spec = QuantSpec {
                adc_bits: bits,
                ..QuantSpec::default()
            };
            let mut xb = Crossbar::program(&w, rows, cols, spec);
            xb.calibrate(&[x.clone()]);
            let y = xb.smac(&x);
            let err: f64 = y
                .iter()
                .zip(want.iter())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            errs.push(err);
        }
        assert!(errs[0] >= errs[1] && errs[1] >= errs[2], "{errs:?}");
    }

    #[test]
    fn uncalibrated_crossbar_still_functions() {
        // default unit full-scale clips hard but must not crash
        let w = random_tile(16, 8, 4, 0.1);
        let mut xb = Crossbar::program(&w, 16, 8, QuantSpec::default());
        assert!(!xb.is_calibrated());
        let y = xb.smac(&[0.5; 16]);
        assert_eq!(y.len(), 8);
    }

    #[test]
    fn smac_counter_increments() {
        let w = random_tile(8, 8, 5, 0.1);
        let mut xb = Crossbar::program(&w, 8, 8, QuantSpec::default());
        xb.calibrate(&[vec![1.0; 8]]);
        xb.smac(&[1.0; 8]);
        xb.smac(&[0.5; 8]);
        assert_eq!(xb.smacs(), 2);
    }

    #[test]
    fn smac_into_with_is_bit_identical_across_pools() {
        // 32768×32 puts the input over PAR_QUANT_MIN and the MAC over
        // PAR_MAC_MIN, so both parallel phases actually engage; the
        // result must still match the sequential bytes exactly.
        let (rows, cols) = (1usize << 15, 32usize);
        let w = random_tile(rows, cols, 11, 0.05);
        let x = random_tile(rows, 1, 12, 1.0);
        let mut xb = Crossbar::program(&w, rows, cols, QuantSpec::default());
        xb.calibrate(&[x.clone()]);
        let mut seq = Vec::new();
        xb.smac_into_with(Pool::sequential(), &x, &mut seq);
        for threads in [2usize, 8] {
            let mut par = Vec::new();
            xb.smac_into_with(Pool::new(threads), &x, &mut par);
            assert_eq!(seq.len(), par.len());
            for (i, (a, b)) in seq.iter().zip(par.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "col {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn nonvolatile_weights_survive_relaxation_within_bound() {
        let (rows, cols) = (32, 32);
        let w = random_tile(rows, cols, 6, 0.05);
        let x = random_tile(rows, 1, 8, 1.0);
        let mut xb = Crossbar::program(&w, rows, cols, QuantSpec::default());
        xb.calibrate(&[x.clone()]);
        let clean = xb.smac(&x);
        xb.array_mut().relax(0.005, 9);
        let noisy = xb.smac(&x);
        let rel: f64 = clean
            .iter()
            .zip(noisy.iter())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
            / clean.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt().max(1e-12);
        assert!(rel < 0.1, "0.5% relaxation moves outputs <10%: {rel}");
    }
}
