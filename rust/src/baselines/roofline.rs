//! GPU roofline sanity model: decode throughput of a memory-bound LLM on a
//! GPU is bounded by HBM bandwidth / bytes-per-token. Used to check that
//! the published Table III baselines are physically plausible and to give
//! the benches an analytic comparison curve.

use crate::models::LlamaConfig;

/// A GPU described by its roofline parameters.
#[derive(Debug, Clone)]
pub struct GpuRoofline {
    pub name: String,
    /// HBM bandwidth, bytes/s.
    pub hbm_bps: f64,
    /// Peak dense compute, FLOP/s (fp16/bf16 tensor).
    pub peak_flops: f64,
    /// Board power, W.
    pub tdp_w: f64,
}

impl GpuRoofline {
    pub fn a100() -> GpuRoofline {
        GpuRoofline {
            name: "A100-80G".into(),
            hbm_bps: 2.0e12,
            peak_flops: 312e12,
            tdp_w: 400.0,
        }
    }

    pub fn h100() -> GpuRoofline {
        GpuRoofline {
            name: "H100-SXM".into(),
            hbm_bps: 3.35e12,
            peak_flops: 990e12,
            tdp_w: 700.0,
        }
    }

    /// Decode roofline, tokens/s: every output token must stream all
    /// decoder weights (batch 1, no reuse) at `bytes_per_param`.
    pub fn decode_tokens_per_s(&self, model: &LlamaConfig, bytes_per_param: f64) -> f64 {
        let bytes_per_token = model.decoder_params() as f64 * bytes_per_param;
        self.hbm_bps / bytes_per_token
    }

    /// Compute-bound prefill bound, tokens/s (2 FLOPs per param per token).
    pub fn prefill_tokens_per_s(&self, model: &LlamaConfig) -> f64 {
        self.peak_flops / (2.0 * model.decoder_params() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::platforms::platform;

    #[test]
    fn published_numbers_below_roofline() {
        // vendor-published decode throughput must not exceed the roofline
        // at the serving precision (the published H100 number implies fp8
        // weights — 274 tok/s > the fp16 roofline of ~239 tok/s).
        let m = LlamaConfig::llama3_8b();
        let a100 = GpuRoofline::a100();
        let h100 = GpuRoofline::h100();
        assert!(
            platform("NV A100").unwrap().tokens_per_s < a100.decode_tokens_per_s(&m, 2.0),
            "A100 published number is fp16-feasible"
        );
        assert!(
            platform("NV H100").unwrap().tokens_per_s < h100.decode_tokens_per_s(&m, 1.0),
            "H100 published number is fp8-feasible"
        );
        // and within 2 orders of magnitude (plausibility, batch-1 overheads)
        assert!(
            platform("NV H100").unwrap().tokens_per_s > h100.decode_tokens_per_s(&m, 1.0) / 100.0
        );
    }

    #[test]
    fn h100_faster_than_a100() {
        let m = LlamaConfig::llama3_8b();
        assert!(
            GpuRoofline::h100().decode_tokens_per_s(&m, 2.0)
                > GpuRoofline::a100().decode_tokens_per_s(&m, 2.0)
        );
    }

    #[test]
    fn prefill_compute_bound_exceeds_decode() {
        let m = LlamaConfig::llama3_8b();
        let g = GpuRoofline::h100();
        assert!(g.prefill_tokens_per_s(&m) > g.decode_tokens_per_s(&m, 2.0));
    }
}
