//! Baseline platform models for Table III: published Llama-8B (1024/1024,
//! batch 1) throughput/power for each comparison platform, plus a simple
//! roofline model used for sanity checks and the A100/H100 speedup math.

mod platforms;
mod roofline;

pub use platforms::{platform, Platform, PlatformKind, TABLE3_PLATFORMS};
pub use roofline::GpuRoofline;
