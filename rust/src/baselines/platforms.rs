//! Published baseline numbers (paper Table III): Llama-8B, context
//! 1024/1024, batch size 1, Nvidia H100 as the normalization baseline.
//!
//! These are *inputs* — the paper itself compares against vendor-published
//! or prior-work numbers; PICNIC's own row is computed by our simulator.


/// Baseline architecture category (Table III "Architecture" row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlatformKind {
    HybridPimNmc,
    NandFlashPim,
    MultiCoreGpu,
    SocNpu,
    WaferScale,
}

/// One comparison platform.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: &'static str,
    pub kind: PlatformKind,
    /// Llama-8B decode throughput, tokens/s.
    pub tokens_per_s: f64,
    /// Average power, W.
    pub power_w: f64,
}

impl Platform {
    pub fn tokens_per_j(&self) -> f64 {
        self.tokens_per_s / self.power_w
    }

    /// Speedup vs a baseline platform (Table III's "Speedup^" row).
    pub fn speedup_vs(&self, base: &Platform) -> f64 {
        self.tokens_per_s / base.tokens_per_s
    }

    /// Efficiency improvement vs a baseline (Table III's last row).
    pub fn efficiency_vs(&self, base: &Platform) -> f64 {
        self.tokens_per_j() / base.tokens_per_j()
    }
}

/// The six non-PICNIC columns of Table III.
pub const TABLE3_PLATFORMS: &[Platform] = &[
    Platform {
        name: "TransPIM",
        kind: PlatformKind::HybridPimNmc,
        tokens_per_s: 270.0,
        power_w: 40.0,
    },
    Platform {
        name: "Cambricon-LLM",
        kind: PlatformKind::NandFlashPim,
        tokens_per_s: 36.34,
        power_w: 36.3,
    },
    Platform {
        name: "NV A100",
        kind: PlatformKind::MultiCoreGpu,
        tokens_per_s: 78.36,
        power_w: 200.0,
    },
    Platform {
        name: "NV H100",
        kind: PlatformKind::MultiCoreGpu,
        tokens_per_s: 274.26,
        power_w: 280.0,
    },
    Platform {
        name: "Apple M4-Max",
        kind: PlatformKind::SocNpu,
        tokens_per_s: 69.77,
        power_w: 80.0,
    },
    Platform {
        name: "Cerebras-2",
        kind: PlatformKind::WaferScale,
        tokens_per_s: 1800.0,
        power_w: 15000.0,
    },
];

/// Look up a baseline by (case-insensitive) name.
pub fn platform(name: &str) -> Option<&'static Platform> {
    TABLE3_PLATFORMS
        .iter()
        .find(|p| p.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn by(name: &str) -> &'static Platform {
        platform(name).unwrap()
    }

    #[test]
    fn table3_ratios_reproduce() {
        let h100 = by("NV H100");
        // Table III row "Speedup" (H100 = 1×)
        assert!((by("TransPIM").speedup_vs(h100) - 0.98).abs() < 0.01);
        assert!((by("Cambricon-LLM").speedup_vs(h100) - 0.13).abs() < 0.01);
        assert!((by("NV A100").speedup_vs(h100) - 0.29).abs() < 0.01);
        assert!((by("Apple M4-Max").speedup_vs(h100) - 0.25).abs() < 0.01);
        assert!((by("Cerebras-2").speedup_vs(h100) - 6.57).abs() < 0.01);
        // Table III row "Efficiency improvement"
        assert!((by("TransPIM").efficiency_vs(h100) - 6.94).abs() < 0.1);
        assert!((by("NV A100").efficiency_vs(h100) - 0.4).abs() < 0.01);
        assert!((by("Apple M4-Max").efficiency_vs(h100) - 0.89).abs() < 0.01);
        assert!((by("Cerebras-2").efficiency_vs(h100) - 0.13).abs() < 0.01);
    }

    #[test]
    fn tokens_per_j_column() {
        assert!((by("NV H100").tokens_per_j() - 0.98).abs() < 0.01);
        assert!((by("NV A100").tokens_per_j() - 0.39).abs() < 0.01);
        assert!((by("TransPIM").tokens_per_j() - 6.8).abs() < 0.1);
        assert!((by("Cerebras-2").tokens_per_j() - 0.12).abs() < 0.01);
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(platform("nv h100").is_some());
        assert!(platform("unknown").is_none());
    }
}
