//! Ablation bench (DESIGN.md design-choice callouts): quantify why the
//! paper's mapping decisions win.
//!
//! A1 — spatial mapping: Fig 6 column-channel serpentine vs a naive
//!      row-major band placement. Metric: locality cost (mean reduction-
//!      partner distance) and spanning-tree depth/hops per channel.
//! A2 — KV-cache allocation: cyclic (paper) vs fill-first. Metric:
//!      scratchpad imbalance across sequence lengths.
//! A3 — CCPG cluster size: 1/2/4/8 tiles per cluster. Metric: system power
//!      and wake counts on Llama-8B.
//!
//! Run: `cargo bench --bench ablation`

mod harness;

use picnic::config::{CcpgConfig, PicnicConfig, SystemConfig};
use picnic::mapper::collective::SpanningTree;
use picnic::mapper::{KvCache, Placement};
use picnic::models::{LlamaConfig, Workload};
use picnic::sim::AnalyticSim;

fn main() {
    harness::section("A1 — spatial mapping: Fig 6 column channels vs row-major bands");
    for model in [LlamaConfig::llama32_1b(), LlamaConfig::llama3_8b()] {
        let layer = model.layers()[0]; // attention layer
        let fig6 =
            Placement::for_layer(&layer, model.d_model, model.kv_width(), 32, 256).unwrap();
        let naive =
            Placement::for_layer_rowmajor(&layer, model.d_model, model.kv_width(), 32, 256)
                .unwrap();
        let tree_stats = |p: &Placement| {
            let mut depth = 0usize;
            let mut hops = 0usize;
            for ch in &p.channels {
                let t = SpanningTree::build(&ch.assignment.routers, p.grid_w);
                depth = depth.max(t.depth);
                hops += t.total_hops;
            }
            (depth, hops)
        };
        let (d_f, h_f) = tree_stats(&fig6);
        let (d_n, h_n) = tree_stats(&naive);
        println!(
            "{:<14} locality cost: fig6 {:>6.2} vs row-major {:>6.2}   tree: depth {} vs {}, hops {} vs {}",
            model.name,
            fig6.locality_cost(),
            naive.locality_cost(),
            d_f,
            d_n,
            h_f,
            h_n
        );
        assert!(
            fig6.locality_cost() <= naive.locality_cost(),
            "Fig 6 layout must not lose on locality"
        );
    }

    harness::section("A2 — KV cache: cyclic vs fill-first scratchpad allocation");
    for seq in [64usize, 512, 1000] {
        // cyclic (the paper's scheme)
        let mut cyclic = KvCache::new((0..16).collect(), 16, 4096);
        for _ in 0..seq {
            cyclic.append().unwrap();
        }
        // fill-first baseline: pack scratchpad 0 before moving on
        let per_pad = 4096 / 16;
        let mut fill: Vec<usize> = vec![0; 16];
        for t in 0..seq {
            fill[(t / per_pad).min(15)] += 1;
        }
        let fill_imb = fill.iter().max().unwrap() - fill.iter().min().unwrap();
        println!(
            "seq {seq:>5}: imbalance cyclic {} vs fill-first {}",
            cyclic.imbalance(),
            fill_imb
        );
        assert!(cyclic.imbalance() <= 1, "paper's claim: balanced at any length");
    }

    harness::section("A3 — CCPG cluster size sweep (Llama-8B, 1024/1024)");
    for tiles_per_cluster in [1usize, 2, 4, 8] {
        let cfg = PicnicConfig {
            ccpg: CcpgConfig {
                enabled: true,
                tiles_per_cluster,
                ..CcpgConfig::default()
            },
            ..PicnicConfig::default()
        };
        let sim = AnalyticSim::new(cfg);
        let r = sim
            .run(&LlamaConfig::llama3_8b(), &Workload::new(1024, 1024))
            .unwrap();
        println!(
            "cluster={tiles_per_cluster}: {:.1} tok/s, {:.3} W, {:.2} tok/J",
            r.stats.tokens_per_s, r.stats.avg_power_w, r.stats.tokens_per_j
        );
    }
    println!(
        "(paper picks 4: small clusters gate more but wake more; the sweep shows the knee)"
    );

    harness::section("A4 — mesh dimension sensitivity (tile capacity vs paper's 32×32)");
    for dim in [16usize, 32, 64] {
        let sys = SystemConfig::tiny(dim);
        println!(
            "mesh {dim}×{dim}: {} weights/tile, {} DMAC/cycle",
            sys.weights_per_tile(),
            sys.tile_dmac_per_cycle()
        );
    }
}
