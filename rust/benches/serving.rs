//! Bench: the event-driven pipeline-parallel serving stack — simulated
//! decode throughput vs. batch size at a fixed model, plus host-side
//! timing of the scheduler itself, plus a **speculative-decode
//! acceptance-rate sweep** at the largest batch, plus a **multi-tenant
//! sweep** (1 vs 2 vs 4 equal-weight tenants, shared vs dedicated
//! spans, symmetric workload), plus an **open-loop traffic sweep**
//! (Poisson and bursty arrivals at 30/60/90% of measured capacity,
//! thousands of seeded chat-mixture requests per point), plus a
//! **fault-injection sweep** (photonic bit-error rate × offered load,
//! with zero-fault-identity, same-seed-determinism and tile-kill-storm
//! probes), plus a **KV-reuse sweep** (shared-prefix hit rate ×
//! utilization with a reuse-off baseline per utilization), plus a
//! **scale-out sweep** (8B and 70B × 1/2/4 chiplet packages on the
//! switched photonic fabric, rate→∞ open-loop, with a fabric-off
//! baseline per model). Dumps
//! `BENCH_serving.json` (schema 7 — see EXPERIMENTS.md §BENCH_serving
//! schema for the field-by-field contract): one `points` entry per
//! batch size with simulated tokens/s, the serialized PR-2 reference,
//! TTFT and p99; a `spec` block with one entry per acceptance rate next
//! to the non-speculative batch-8 reference; a `tenancy` block with
//! per-tenant throughputs and Jain's fairness index per configuration;
//! an `open_loop` block with a closed-loop parity check (every arrival
//! at cycle 0 must match the batch-8 closed-loop run) and p50/p95/p99
//! TTFT / per-token / end-to-end latency per (shape × utilization)
//! point; a `faults` block with the three probe verdicts, the storm's
//! terminal-state accounting, and one entry per (bit-error rate ×
//! utilization) with degradation counters; and a `kv_reuse` block — one
//! entry per (hit rate × utilization) plus the reuse-off baselines,
//! each nesting its schedule-derived output in a `metrics` sub-object
//! so the hit=0 row can be compared byte-for-byte against the baseline;
//! and a `scale_out` block — one entry per (model × package count) plus
//! a fabric-off baseline per model, each fitting row nesting the same
//! `metrics` sub-object so the packages=1 row can be compared
//! byte-for-byte against the fabric-off baseline (the 70B preset's
//! 1-package row instead records `fits = false` with the mapper's
//! error).
//! CI validates batch-8 > 2× batch-1, spec acceptance=1.0 ≥ the
//! non-speculative reference, equal-weight 2-tenant fairness
//! (Jain ≥ 0.9 on the symmetric workload), open/closed parity within 5%,
//! that p99 TTFT grows with offered load, the faults-block probe
//! verdicts plus storm conservation, and the kv_reuse identity verdict
//! plus hit-rate monotonicity (prefill cycles saved strictly rising,
//! p99 TTFT non-increasing), the scale_out identity verdict plus
//! package-count throughput monotonicity (strictly rising, each step
//! ≥ 1.5× on the fitting rows), then archives the file as the
//! `BENCH_serving` artifact.
//!
//! Every sweep's points are independent simulations, so they fan out
//! across a [`Pool`] sized by `PICNIC_THREADS` (wall-clock `bench` rows
//! wrap each fan-out). The simulations themselves are seeded and
//! single-threaded, and results are spliced back in fixed sweep order —
//! `BENCH_serving.json` is **byte-identical at any thread count** (CI
//! diffs a `PICNIC_THREADS=1` reference run against the parallel one).
//! Run: `cargo bench --bench serving`

mod harness;

use picnic::config::{
    FabricConfig, FaultConfig, KillSpec, KvReuseConfig, PicnicConfig, SloSpec, SpecDecodeConfig,
    TenantSpec, TenantsConfig,
};
use picnic::coordinator::{
    serialized_workload_cycles, BatchPolicy, LatencyKind, Metrics, PipelineStats, Server,
    ServerConfig, SubmitSpec, TenantStats,
};
use picnic::models::{LlamaConfig, PrefixSpec, TrafficModel};
use picnic::sim::AnalyticSim;
use picnic::util::json::{self, Json};
use picnic::util::Pool;

const MODEL: &str = "1b";
const PROMPT: usize = 256;
const GEN: usize = 32;
/// Spec-decode sweep shape: draft burst and draft-model cost ratio are
/// fixed; the acceptance rate sweeps.
const SPEC_BATCH: usize = 8;
const SPEC_DRAFT_LEN: usize = 4;
const SPEC_COST_RATIO: f64 = 0.2;
/// Multi-tenant sweep shape: total concurrent requests stays at the
/// largest batch row while the tenant count and span mode sweep.
const TENANT_REQUESTS: usize = 8;
/// Open-loop sweep shape: seeded chat-mixture traffic, thousands of
/// requests per (shape × utilization) point.
const OPEN_SEED: u64 = 11;
const OPEN_CAPACITY_REQUESTS: usize = 512;
const OPEN_SWEEP_REQUESTS: usize = 2000;
/// Fault sweep shape: the fault model's own seed, the tile-kill fan of
/// the storm probe, and a lighter request count per sweep point (the
/// degradation signal saturates well before the open-loop tails do).
const FAULT_SEED: u64 = 13;
const FAULT_STORM_TILES: u32 = 8;
const FAULT_SWEEP_REQUESTS: usize = 500;
/// KV-reuse sweep shape: shared-prefix hit rate × utilization over the
/// seeded Poisson chat mixture, with a reuse-off baseline per
/// utilization (the hit=0 row must reproduce it byte for byte).
const KV_HIT_RATES: [f64; 4] = [0.0, 0.3, 0.6, 0.9];
const KV_UTILIZATIONS: [f64; 2] = [0.4, 0.7];
const KV_SWEEP_REQUESTS: usize = 600;
const KV_POOL_TOKENS: usize = 1 << 16;
/// Scale-out sweep shape: a package-fitting model (8B replicates
/// data-parallel across packages) and a package-outgrowing one (the 70B
/// preset pipelines across two), at 1/2/4 packages plus a fabric-off
/// baseline per model. Every request arrives at cycle 0 (rate→∞ open
/// loop) and the batch ceiling exceeds the deepest pipeline, so each
/// replica's bottleneck stage saturates and replication is visible as
/// aggregate throughput — not a queueing artifact.
const SCALE_MODELS: [&str; 2] = ["8b", "70b"];
const SCALE_PACKAGE_COUNTS: [usize; 3] = [1, 2, 4];
const SCALE_REQUESTS: usize = 768;
const SCALE_MAX_BATCH: usize = 1 << 10;

fn policy(batch: usize) -> BatchPolicy {
    BatchPolicy {
        max_batch: batch.max(1),
        kv_budget: 1 << 22,
        ..BatchPolicy::default()
    }
}

fn server(batch: usize) -> Server {
    Server::new(ServerConfig {
        picnic: PicnicConfig::default(),
        model: LlamaConfig::by_name(MODEL).expect("model"),
        policy: policy(batch),
        threads: 0,
    })
}

fn run_once(batch: usize) -> Metrics {
    let mut s = server(batch);
    for _ in 0..batch {
        s.enqueue(SubmitSpec::new(PROMPT, GEN)).expect("enqueue");
    }
    s.run_to_completion().expect("run");
    s.metrics.clone()
}

/// One tenancy-sweep run: `n_tenants` equal-weight tenants (all shared
/// or all dedicated), `TENANT_REQUESTS` identical requests spread
/// round-robin — a symmetric workload, so any throughput skew is the
/// scheduler's doing.
fn run_tenancy_once(n_tenants: usize, dedicated: bool) -> (Metrics, Vec<TenantStats>, f64) {
    let tenants = TenantsConfig {
        tenants: (0..n_tenants)
            .map(|i| TenantSpec {
                name: format!("t{i}"),
                weight: 1.0,
                kv_budget: 0,
                dedicated,
                slo: SloSpec::default(),
            })
            .collect(),
    };
    let picnic = PicnicConfig {
        tenants,
        ..PicnicConfig::default()
    };
    let mut s = Server::new(ServerConfig {
        picnic,
        model: LlamaConfig::by_name(MODEL).expect("model"),
        policy: policy(TENANT_REQUESTS),
        threads: 0,
    });
    for i in 0..TENANT_REQUESTS {
        s.enqueue(SubmitSpec::new(PROMPT, GEN).tenant(i % n_tenants))
            .expect("enqueue");
    }
    s.run_to_completion().expect("run");
    let stats = s.tenant_stats();
    let jain = s.fairness_index();
    (s.metrics.clone(), stats, jain)
}

fn run_spec_once(batch: usize, acceptance: f64) -> (Metrics, PipelineStats) {
    let picnic = PicnicConfig {
        spec_decode: SpecDecodeConfig {
            enabled: true,
            draft_len: SPEC_DRAFT_LEN,
            acceptance_rate: acceptance,
            draft_cost_ratio: SPEC_COST_RATIO,
        },
        ..PicnicConfig::default()
    };
    let mut s = Server::new(ServerConfig {
        picnic,
        model: LlamaConfig::by_name(MODEL).expect("model"),
        policy: policy(batch),
        threads: 0,
    });
    for _ in 0..batch {
        s.enqueue(SubmitSpec::new(PROMPT, GEN)).expect("enqueue");
    }
    s.run_to_completion().expect("run");
    (s.metrics.clone(), s.pipeline_stats())
}

/// Closed-loop parity probe: the same `batch` fixed-shape requests as
/// `run_once`, but through the open-loop path with every arrival
/// stamped at cycle 0. The schedules must coincide — this pins the
/// rate→∞ limit of the open-loop machinery to the closed-loop result.
fn run_open_parity(batch: usize) -> Metrics {
    let mut s = server(batch);
    for _ in 0..batch {
        s.enqueue(SubmitSpec::new(PROMPT, GEN).arrives_at(0))
            .expect("enqueue");
    }
    s.run_to_completion().expect("run");
    s.metrics.clone()
}

/// Capacity probe: `n` seeded chat-mixture requests all arriving at
/// cycle 0 (infinite offered load) → sustainable tokens/s for this
/// model/policy, plus the mixture's mean generation length (used to
/// convert a utilization target into an arrival rate).
fn run_capacity(n: usize, freq: f64) -> (f64, f64) {
    let model = TrafficModel::poisson(OPEN_SEED, 1.0);
    let mut s = server(SPEC_BATCH);
    let mut gen_tokens = 0u64;
    for (_, spec) in model.stream(freq).take(n) {
        gen_tokens += spec.max_new_tokens as u64;
        s.enqueue(spec.arrives_at(0)).expect("enqueue");
    }
    s.run_to_completion().expect("run");
    let mean_gen = gen_tokens as f64 / n as f64;
    (s.metrics.throughput_tokens_per_s(), mean_gen)
}

/// One open-loop sweep point: `n` requests from the seeded stream at
/// `rate_rps`, Poisson or bursty. Returns the metrics and the offered
/// token rate (mixture generation tokens per arrival-clock second).
fn run_open_loop(shape: &str, rate_rps: f64, n: usize, freq: f64) -> (Metrics, f64) {
    let model = match shape {
        "bursty" => TrafficModel::bursty(OPEN_SEED, rate_rps),
        _ => TrafficModel::poisson(OPEN_SEED, rate_rps),
    };
    let mut s = server(SPEC_BATCH);
    let mut offered_tokens = 0u64;
    let mut last_arrival = 0u64;
    for (arrival, spec) in model.stream(freq).take(n) {
        offered_tokens += spec.max_new_tokens as u64;
        last_arrival = arrival;
        s.enqueue(spec).expect("enqueue");
    }
    s.run_to_completion().expect("run");
    let span_s = (last_arrival as f64 / freq).max(1e-12);
    (s.metrics.clone(), offered_tokens as f64 / span_s)
}

fn fault_cfg(ber: f64, kills: Vec<KillSpec>) -> FaultConfig {
    FaultConfig {
        enabled: true,
        seed: FAULT_SEED,
        link_ber: ber,
        kills,
        ..FaultConfig::default()
    }
}

/// Closed-loop run with a fault model: the batch-8 fixed-shape workload
/// of `run_once` under injected faults.
fn run_fault_closed(batch: usize, faults: FaultConfig) -> (Metrics, PipelineStats) {
    let mut s = Server::new(ServerConfig {
        picnic: PicnicConfig {
            faults,
            ..PicnicConfig::default()
        },
        model: LlamaConfig::by_name(MODEL).expect("model"),
        policy: policy(batch),
        threads: 0,
    });
    for _ in 0..batch {
        s.enqueue(SubmitSpec::new(PROMPT, GEN)).expect("enqueue");
    }
    s.run_to_completion().expect("run");
    (s.metrics.clone(), s.pipeline_stats())
}

/// One fault-sweep point: the seeded Poisson chat mixture at `rate_rps`
/// with transient bit errors at `ber` on every chip-to-chip hop.
fn run_fault_open(ber: f64, rate_rps: f64, n: usize, freq: f64) -> (Metrics, PipelineStats) {
    let model = TrafficModel::poisson(OPEN_SEED, rate_rps);
    let mut s = Server::new(ServerConfig {
        picnic: PicnicConfig {
            faults: fault_cfg(ber, Vec::new()),
            ..PicnicConfig::default()
        },
        model: LlamaConfig::by_name(MODEL).expect("model"),
        policy: policy(SPEC_BATCH),
        threads: 0,
    });
    for (_, spec) in model.stream(freq).take(n) {
        s.enqueue(spec).expect("enqueue");
    }
    s.run_to_completion().expect("run");
    (s.metrics.clone(), s.pipeline_stats())
}

/// One KV-reuse sweep point: the seeded Poisson chat mixture at
/// `rate_rps`. `hit_rate = Some(h)` enables the reuse layer and
/// attaches pooled-prefix token ids at hit probability `h`; `None` is
/// the reuse-off baseline (no cache, no tokens) the hit=0 row must
/// reproduce byte for byte.
fn run_kv_open(hit_rate: Option<f64>, rate_rps: f64, n: usize, freq: f64) -> (Metrics, PipelineStats) {
    let kv_reuse = match hit_rate {
        Some(hit) => KvReuseConfig {
            enabled: true,
            pool_tokens: KV_POOL_TOKENS,
            hit_rate: hit,
            ..KvReuseConfig::default()
        },
        None => KvReuseConfig::default(),
    };
    let mut model = TrafficModel::poisson(OPEN_SEED, rate_rps);
    if kv_reuse.enabled {
        model = model.with_shared_prefixes(PrefixSpec::from(&kv_reuse));
    }
    let mut s = Server::new(ServerConfig {
        picnic: PicnicConfig {
            kv_reuse,
            ..PicnicConfig::default()
        },
        model: LlamaConfig::by_name(MODEL).expect("model"),
        policy: policy(SPEC_BATCH),
        threads: 0,
    });
    for (_, spec) in model.stream(freq).take(n) {
        s.enqueue(spec).expect("enqueue");
    }
    s.run_to_completion().expect("run");
    (s.metrics.clone(), s.pipeline_stats())
}

/// One scale-out sweep point: `n` fixed-shape requests all arriving at
/// cycle 0 on `model` over a `packages`-package fabric (`packages = 0`
/// is the fabric-off baseline). Errs when the model does not fit the
/// fabric — the 70B preset's expected 1-package outcome.
fn run_scale_out(
    model: &str,
    packages: usize,
    n: usize,
) -> picnic::Result<(Metrics, PipelineStats)> {
    let mut picnic = PicnicConfig::default();
    if packages > 0 {
        picnic.fabric = FabricConfig {
            enabled: true,
            packages,
            ..FabricConfig::default()
        };
    }
    let mut s = Server::new(ServerConfig {
        picnic,
        model: LlamaConfig::by_name(model).expect("model"),
        policy: BatchPolicy {
            max_batch: SCALE_MAX_BATCH,
            kv_budget: 1 << 22,
            ..BatchPolicy::default()
        },
        threads: 0,
    });
    for _ in 0..n {
        s.enqueue(SubmitSpec::new(PROMPT, GEN).arrives_at(0))
            .expect("enqueue");
    }
    s.run_to_completion()?;
    Ok((s.metrics.clone(), s.pipeline_stats()))
}

fn main() {
    let pool = Pool::from_env();
    harness::section("pipeline-parallel serving: throughput vs batch size");
    println!("  sweep fan-out: {} worker(s)", pool.threads());
    let cfg = PicnicConfig::default();
    let model = LlamaConfig::by_name(MODEL).expect("model");
    let sim = AnalyticSim::new(cfg.clone());
    let freq = cfg.system.frequency_hz;
    let chunk = BatchPolicy::default().prefill_chunk;

    let batches = [1usize, 2, 4, 8];
    let mut batch_runs: Vec<Metrics> = Vec::new();
    harness::bench("serve/batch_sweep_x4", 0, 1, || {
        batch_runs = pool.par_map_index(batches.len(), |i| run_once(batches[i]));
    });
    let mut points: Vec<Json> = Vec::new();
    let mut reference_tps = 0.0f64;
    for (&batch, m) in batches.iter().zip(batch_runs.iter()) {
        assert_eq!(m.requests.len(), batch);

        // serialized PR-2 reference: the same jobs, each monopolizing the
        // whole fabric back to back
        let serialized =
            serialized_workload_cycles(&sim, &cfg, &model, batch, PROMPT, GEN, chunk)
                .expect("plan");
        let ser_tps = m.total_tokens as f64 / (serialized as f64 / freq);
        if batch == SPEC_BATCH {
            reference_tps = m.throughput_tokens_per_s();
        }
        let ttft = m.summary(LatencyKind::Ttft);
        let total = m.summary(LatencyKind::Total);
        println!(
            "  batch {batch}: {:>8.1} tokens/s pipelined   {:>8.1} tokens/s serialized   \
             mean TTFT {:.3} ms   p99 {:.3} ms",
            m.throughput_tokens_per_s(),
            ser_tps,
            1e3 * ttft.mean_s,
            1e3 * total.p99_s,
        );
        points.push(json::obj(vec![
            ("batch", json::num(batch as f64)),
            ("tokens_per_s", json::num(m.throughput_tokens_per_s())),
            ("serialized_tokens_per_s", json::num(ser_tps)),
            ("mean_ttft_s", json::num(ttft.mean_s)),
            ("p99_total_s", json::num(total.p99_s)),
        ]));
    }

    harness::section("speculative decode: throughput vs acceptance rate");
    println!(
        "  batch {SPEC_BATCH}, draft_len {SPEC_DRAFT_LEN}, draft cost ratio {SPEC_COST_RATIO} \
         (non-speculative reference: {reference_tps:.1} tokens/s)"
    );
    let accepts = [0.0f64, 0.25, 0.5, 0.75, 1.0];
    let mut spec_runs: Vec<(Metrics, PipelineStats)> = Vec::new();
    harness::bench("serve/spec_sweep_x5", 0, 1, || {
        spec_runs = pool.par_map_index(accepts.len(), |i| run_spec_once(SPEC_BATCH, accepts[i]));
    });
    let mut spec_points: Vec<Json> = Vec::new();
    for (&acceptance, (m, p)) in accepts.iter().zip(spec_runs.iter()) {
        let ttft = m.summary(LatencyKind::Ttft);
        let total = m.summary(LatencyKind::Total);
        println!(
            "  accept {acceptance:.2}: {:>8.1} tokens/s ({:+6.1}% vs non-spec)   \
             {} rounds, {} drafted, {} rolled back   mean TTFT {:.3} ms",
            m.throughput_tokens_per_s(),
            100.0 * (m.throughput_tokens_per_s() / reference_tps - 1.0),
            p.spec_rounds,
            p.spec_drafted,
            p.spec_rolled_back,
            1e3 * ttft.mean_s,
        );
        spec_points.push(json::obj(vec![
            ("acceptance", json::num(acceptance)),
            ("tokens_per_s", json::num(m.throughput_tokens_per_s())),
            ("mean_ttft_s", json::num(ttft.mean_s)),
            ("p99_total_s", json::num(total.p99_s)),
            ("spec_rounds", json::num(p.spec_rounds as f64)),
            ("spec_drafted", json::num(p.spec_drafted as f64)),
            ("spec_committed", json::num(p.spec_committed as f64)),
            ("spec_rolled_back", json::num(p.spec_rolled_back as f64)),
        ]));
    }

    harness::section("multi-tenant sharding: tenants × span mode (symmetric workload)");
    println!("  {TENANT_REQUESTS} identical requests round-robined across equal-weight tenants");
    let tenancy_combos: Vec<(usize, bool)> = [1usize, 2, 4]
        .iter()
        .flat_map(|&n| [(n, false), (n, true)])
        .collect();
    let mut tenancy_runs: Vec<(Metrics, Vec<TenantStats>, f64)> = Vec::new();
    harness::bench("serve/tenancy_sweep_x6", 0, 1, || {
        tenancy_runs = pool.par_map_index(tenancy_combos.len(), |i| {
            let (n_tenants, dedicated) = tenancy_combos[i];
            run_tenancy_once(n_tenants, dedicated)
        });
    });
    let mut tenancy_points: Vec<Json> = Vec::new();
    {
        for (&(n_tenants, dedicated), (m, stats, jain)) in
            tenancy_combos.iter().zip(tenancy_runs.iter())
        {
            let jain = *jain;
            let mode = if dedicated { "dedicated" } else { "shared" };
            println!(
                "  {n_tenants} tenant(s) {mode:<9}: {:>8.1} tokens/s aggregate   jain {jain:.4}   \
                 per-tenant [{}]",
                m.throughput_tokens_per_s(),
                stats
                    .iter()
                    .map(|t| format!("{:.1}", t.tokens_per_s))
                    .collect::<Vec<_>>()
                    .join(", "),
            );
            let per_tenant: Vec<Json> = stats
                .iter()
                .map(|t| {
                    json::obj(vec![
                        ("name", json::s(&t.name)),
                        ("requests", json::num(t.requests as f64)),
                        ("tokens", json::num(t.tokens as f64)),
                        ("tokens_per_s", json::num(t.tokens_per_s)),
                        ("p50_total_s", json::num(t.total.p50_s)),
                        ("p99_total_s", json::num(t.total.p99_s)),
                        ("energy_j", json::num(t.energy_j)),
                    ])
                })
                .collect();
            let ttft = m.summary(LatencyKind::Ttft);
            let total = m.summary(LatencyKind::Total);
            tenancy_points.push(json::obj(vec![
                ("tenants", json::num(n_tenants as f64)),
                ("mode", json::s(mode)),
                ("tokens_per_s", json::num(m.throughput_tokens_per_s())),
                ("mean_ttft_s", json::num(ttft.mean_s)),
                ("p99_total_s", json::num(total.p99_s)),
                ("jain_index", json::num(jain)),
                ("per_tenant", Json::Arr(per_tenant)),
            ]));
        }
    }

    harness::section("open-loop traffic: latency tails vs offered load");
    // The closed-loop reference is the batch sweep's SPEC_BATCH row —
    // already computed above, no re-run.
    let closed_idx = batches
        .iter()
        .position(|&b| b == SPEC_BATCH)
        .expect("batch sweep covers SPEC_BATCH");
    let closed = batch_runs[closed_idx].clone();
    let parity = run_open_parity(SPEC_BATCH);
    let parity_ratio =
        parity.throughput_tokens_per_s() / closed.throughput_tokens_per_s().max(1e-12);
    println!(
        "  parity (rate→∞ vs closed-loop batch-{SPEC_BATCH}): {:.1} vs {:.1} tokens/s \
         (ratio {parity_ratio:.4})",
        parity.throughput_tokens_per_s(),
        closed.throughput_tokens_per_s(),
    );
    let (capacity_tps, mean_gen) = run_capacity(OPEN_CAPACITY_REQUESTS, freq);
    println!(
        "  capacity ({OPEN_CAPACITY_REQUESTS} chat-mixture requests at cycle 0): \
         {capacity_tps:.1} tokens/s, mean generation {mean_gen:.1} tokens"
    );
    let open_combos: Vec<(&str, f64)> = ["poisson", "bursty"]
        .iter()
        .flat_map(|&shape| [0.3f64, 0.6, 0.9].map(|u| (shape, u)))
        .collect();
    let mut open_runs: Vec<(Metrics, f64)> = Vec::new();
    harness::bench("serve/open_loop_sweep_x6", 0, 1, || {
        open_runs = pool.par_map_index(open_combos.len(), |i| {
            let (shape, utilization) = open_combos[i];
            let rate_rps = utilization * capacity_tps / mean_gen;
            run_open_loop(shape, rate_rps, OPEN_SWEEP_REQUESTS, freq)
        });
    });
    let mut open_points: Vec<Json> = Vec::new();
    {
        for (&(shape, utilization), (m, offered_tps)) in open_combos.iter().zip(open_runs.iter()) {
            let offered_tps = *offered_tps;
            let rate_rps = utilization * capacity_tps / mean_gen;
            let ttft = m.summary(LatencyKind::Ttft);
            let tpot = m.summary(LatencyKind::PerToken);
            let total = m.summary(LatencyKind::Total);
            println!(
                "  {shape:<7} util {utilization:.1} ({rate_rps:>8.1} req/s): \
                 {:>8.1} tokens/s delivered   ttft p50 {:.3} / p99 {:.3} ms   \
                 tpot p99 {:.3} ms",
                m.throughput_tokens_per_s(),
                1e3 * ttft.p50_s,
                1e3 * ttft.p99_s,
                1e3 * tpot.p99_s,
            );
            open_points.push(json::obj(vec![
                ("shape", json::s(shape)),
                ("utilization", json::num(utilization)),
                ("rate_rps", json::num(rate_rps)),
                ("requests", json::num(OPEN_SWEEP_REQUESTS as f64)),
                ("completed", json::num(m.requests.len() as f64)),
                ("shed", json::num(m.shed_count() as f64)),
                ("tokens_per_s", json::num(m.throughput_tokens_per_s())),
                ("offered_tokens_per_s", json::num(offered_tps)),
                ("ttft", ttft.json()),
                ("tpot", tpot.json()),
                ("total", total.json()),
            ]));
        }
    }

    harness::section("fault injection: degradation vs bit-error rate × offered load");
    // Four independent probes, fanned out together:
    //   0 — pay-for-use identity: an *enabled* fault model with every
    //       channel zeroed must reproduce the no-faults baseline bit for
    //       bit;
    //   1, 2 — determinism: same fault seed, same workload, same run;
    //   3 — tile-kill storm: a fan of hard failures mid-run with a
    //       minimal retry budget (the gate is termination with full
    //       accounting, not survival).
    let storm_cfg = FaultConfig {
        enabled: true,
        seed: FAULT_SEED,
        max_retries: 1,
        kills: (0..FAULT_STORM_TILES)
            .map(|tile| KillSpec {
                tile,
                at_s: closed.wall_s / 2.0,
            })
            .collect(),
        ..FaultConfig::default()
    };
    let mut probe_runs: Vec<(Metrics, PipelineStats)> = Vec::new();
    harness::bench("serve/fault_probes_x4", 0, 1, || {
        probe_runs = pool.par_map_index(4, |i| match i {
            0 => run_fault_closed(SPEC_BATCH, fault_cfg(0.0, Vec::new())),
            1 | 2 => run_fault_closed(SPEC_BATCH, fault_cfg(1e-4, Vec::new())),
            _ => run_fault_closed(SPEC_BATCH, storm_cfg.clone()),
        });
    });
    let (ident_m, ident_p) = (&probe_runs[0].0, &probe_runs[0].1);
    let identity_ok = ident_m.total_tokens == closed.total_tokens
        && ident_m.wall_s.to_bits() == closed.wall_s.to_bits()
        && !ident_p.degraded;
    assert!(
        identity_ok,
        "zero-fault run must be byte-identical to the no-faults baseline"
    );
    let (det_a, det_b) = (&probe_runs[1].0, &probe_runs[2].0);
    let determinism_ok = det_a.wall_s.to_bits() == det_b.wall_s.to_bits()
        && det_a.total_tokens == det_b.total_tokens
        && det_a.failed_count() == det_b.failed_count();
    assert!(determinism_ok, "same-seed fault runs must be byte-identical");
    let (storm_m, storm_p) = (&probe_runs[3].0, &probe_runs[3].1);
    let storm_conserved =
        storm_m.requests.len() + storm_m.shed_count() + storm_m.failed_count() == SPEC_BATCH;
    assert!(storm_conserved, "fault storm must account for every request");
    println!(
        "  identity ok: {identity_ok}   determinism ok: {determinism_ok}   \
         storm ({FAULT_STORM_TILES} kills): {} completed / {} failed, {} dead tiles, \
         {} replays",
        storm_m.requests.len(),
        storm_m.failed_count(),
        storm_p.dead_tiles,
        storm_p.job_replays,
    );
    let fault_combos: Vec<(f64, f64)> = [1e-6f64, 1e-4]
        .iter()
        .flat_map(|&ber| [0.3f64, 0.9].map(|u| (ber, u)))
        .collect();
    let mut fault_runs: Vec<(Metrics, PipelineStats)> = Vec::new();
    harness::bench("serve/fault_sweep_x4", 0, 1, || {
        fault_runs = pool.par_map_index(fault_combos.len(), |i| {
            let (ber, utilization) = fault_combos[i];
            let rate_rps = utilization * capacity_tps / mean_gen;
            run_fault_open(ber, rate_rps, FAULT_SWEEP_REQUESTS, freq)
        });
    });
    let mut fault_points: Vec<Json> = Vec::new();
    {
        for (&(ber, utilization), (m, p)) in fault_combos.iter().zip(fault_runs.iter()) {
            let rate_rps = utilization * capacity_tps / mean_gen;
            assert_eq!(
                m.requests.len() + m.shed_count() + m.failed_count(),
                FAULT_SWEEP_REQUESTS,
                "fault sweep point must conserve requests"
            );
            let ttft = m.summary(LatencyKind::Ttft);
            let total = m.summary(LatencyKind::Total);
            println!(
                "  ber {ber:.0e} util {utilization:.1}: {:>8.1} tokens/s   \
                 {} retransmissions ({} cycles)   {} failed   ttft p99 {:.3} ms",
                m.throughput_tokens_per_s(),
                p.link_retransmissions,
                p.link_retransmit_cycles,
                m.failed_count(),
                1e3 * ttft.p99_s,
            );
            fault_points.push(json::obj(vec![
                ("link_ber", json::num(ber)),
                ("utilization", json::num(utilization)),
                ("rate_rps", json::num(rate_rps)),
                ("requests", json::num(FAULT_SWEEP_REQUESTS as f64)),
                ("completed", json::num(m.requests.len() as f64)),
                ("shed", json::num(m.shed_count() as f64)),
                ("failed", json::num(m.failed_count() as f64)),
                ("tokens_per_s", json::num(m.throughput_tokens_per_s())),
                ("link_retransmissions", json::num(p.link_retransmissions as f64)),
                (
                    "link_retransmit_cycles",
                    json::num(p.link_retransmit_cycles as f64),
                ),
                ("job_replays", json::num(p.job_replays as f64)),
                ("ttft", ttft.json()),
                ("total", total.json()),
            ]));
        }
    }

    harness::section("kv reuse: shared-prefix hit rate × offered load");
    println!(
        "  pool {KV_POOL_TOKENS} tokens, {KV_SWEEP_REQUESTS} requests per point; \
         hit=0 must be byte-identical to reuse-off"
    );
    // Per utilization: the reuse-off baseline first, then the hit-rate
    // rows in ascending order (the in-loop monotonicity asserts lean on
    // this ordering).
    let kv_combos: Vec<(Option<f64>, f64)> = KV_UTILIZATIONS
        .iter()
        .flat_map(|&u| {
            std::iter::once((None, u)).chain(KV_HIT_RATES.iter().map(move |&h| (Some(h), u)))
        })
        .collect();
    let mut kv_runs: Vec<(Metrics, PipelineStats)> = Vec::new();
    harness::bench("serve/kv_reuse_sweep_x10", 0, 1, || {
        kv_runs = pool.par_map_index(kv_combos.len(), |i| {
            let (hit, utilization) = kv_combos[i];
            let rate_rps = utilization * capacity_tps / mean_gen;
            run_kv_open(hit, rate_rps, KV_SWEEP_REQUESTS, freq)
        });
    });
    let mut kv_points: Vec<Json> = Vec::new();
    let mut kv_identity_ok = true;
    {
        let mut off_metrics: Option<String> = None;
        let mut base_p99: Option<f64> = None;
        let mut prev: Option<(u64, f64)> = None; // (cycles saved, ttft p99)
        for (&(hit, utilization), (m, p)) in kv_combos.iter().zip(kv_runs.iter()) {
            let rate_rps = utilization * capacity_tps / mean_gen;
            assert_eq!(
                m.requests.len() + m.shed_count() + m.failed_count(),
                KV_SWEEP_REQUESTS,
                "kv sweep point must conserve requests"
            );
            let ttft = m.summary(LatencyKind::Ttft);
            let tpot = m.summary(LatencyKind::PerToken);
            let total = m.summary(LatencyKind::Total);
            // Only schedule-derived output goes in here — the hit=0 row
            // must reproduce the reuse-off baseline's sub-object byte
            // for byte (reuse counters live outside, since the cache
            // itself legitimately differs between off and hit=0).
            let metrics_json = json::obj(vec![
                ("completed", json::num(m.requests.len() as f64)),
                ("shed", json::num(m.shed_count() as f64)),
                ("failed", json::num(m.failed_count() as f64)),
                ("total_tokens", json::num(m.total_tokens as f64)),
                ("wall_s", json::num(m.wall_s)),
                ("tokens_per_s", json::num(m.throughput_tokens_per_s())),
                ("ttft", ttft.json()),
                ("tpot", tpot.json()),
                ("total", total.json()),
            ]);
            let rendered = metrics_json.to_string();
            match hit {
                None => {
                    off_metrics = Some(rendered);
                    base_p99 = None;
                    prev = None;
                    println!(
                        "  util {utilization:.1} reuse off: {:>8.1} tokens/s   \
                         ttft p99 {:.3} ms",
                        m.throughput_tokens_per_s(),
                        1e3 * ttft.p99_s,
                    );
                }
                Some(h) => {
                    if h == 0.0 {
                        assert_eq!(p.prefix_hits, 0, "hit=0 must never match");
                        assert_eq!(p.prefill_cycles_saved, 0, "hit=0 saves nothing");
                        let same = off_metrics.as_deref() == Some(rendered.as_str());
                        kv_identity_ok &= same;
                        assert!(
                            same,
                            "hit=0 must be byte-identical to reuse-off at util {utilization}"
                        );
                        base_p99 = Some(ttft.p99_s);
                    }
                    if let Some((prev_saved, prev_p99)) = prev {
                        assert!(
                            p.prefill_cycles_saved > prev_saved,
                            "prefill cycles saved must rise with hit rate \
                             (util {utilization}, hit {h})"
                        );
                        assert!(
                            ttft.p99_s <= prev_p99,
                            "p99 TTFT must not rise with hit rate \
                             (util {utilization}, hit {h})"
                        );
                    }
                    if h == KV_HIT_RATES[KV_HIT_RATES.len() - 1] {
                        assert!(
                            ttft.p99_s < base_p99.expect("hit=0 row precedes"),
                            "p99 TTFT at the top hit rate must beat hit=0 \
                             (util {utilization})"
                        );
                    }
                    prev = Some((p.prefill_cycles_saved, ttft.p99_s));
                    println!(
                        "  util {utilization:.1} hit {h:.1}  : {:>8.1} tokens/s   \
                         {} hits, {} cached tokens, {} cycles saved   ttft p99 {:.3} ms",
                        m.throughput_tokens_per_s(),
                        p.prefix_hits,
                        p.hit_tokens,
                        p.prefill_cycles_saved,
                        1e3 * ttft.p99_s,
                    );
                }
            }
            kv_points.push(json::obj(vec![
                ("reuse", Json::Bool(hit.is_some())),
                ("hit_rate", json::num(hit.unwrap_or(0.0))),
                ("utilization", json::num(utilization)),
                ("rate_rps", json::num(rate_rps)),
                ("requests", json::num(KV_SWEEP_REQUESTS as f64)),
                ("prefix_hits", json::num(p.prefix_hits as f64)),
                ("hit_tokens", json::num(p.hit_tokens as f64)),
                (
                    "prefill_cycles_saved",
                    json::num(p.prefill_cycles_saved as f64),
                ),
                (
                    "kv_pool_used_tokens",
                    json::num(p.kv_pool_used_tokens as f64),
                ),
                (
                    "kv_pool_evicted_blocks",
                    json::num(p.kv_pool_evicted_blocks as f64),
                ),
                ("metrics", metrics_json),
            ]));
        }
    }

    harness::section("scale-out: throughput vs package count (switched photonic fabric)");
    println!(
        "  {SCALE_REQUESTS} fixed-shape requests at cycle 0 (rate→∞), batch ceiling \
         {SCALE_MAX_BATCH}; packages=1 must be byte-identical to fabric-off"
    );
    let scale_combos: Vec<(&str, usize)> = SCALE_MODELS
        .iter()
        .flat_map(|&m| {
            std::iter::once((m, 0usize)).chain(SCALE_PACKAGE_COUNTS.iter().map(move |&p| (m, p)))
        })
        .collect();
    let mut scale_runs: Vec<std::result::Result<(Metrics, PipelineStats), String>> = Vec::new();
    harness::bench("serve/scale_out_sweep_x8", 0, 1, || {
        scale_runs = pool.par_map_index(scale_combos.len(), |i| {
            let (model, packages) = scale_combos[i];
            run_scale_out(model, packages, SCALE_REQUESTS).map_err(|e| format!("{e:#}"))
        });
    });
    let mut scale_points: Vec<Json> = Vec::new();
    let mut scale_identity_ok = true;
    {
        let mut baseline: Option<String> = None; // fabric-off metrics, per model
        let mut prev_tps: Option<f64> = None; // previous package row, per model
        for (&(model, packages), run) in scale_combos.iter().zip(scale_runs.iter()) {
            if packages == 0 {
                baseline = None;
                prev_tps = None;
            }
            match run {
                Err(e) => {
                    // The only legitimate miss: the 70B preset outgrows a
                    // single default package (1200 tiles > 640).
                    assert!(
                        model == "70b" && packages == 1,
                        "unexpected scale-out failure ({model}, {packages} packages): {e}"
                    );
                    assert!(
                        e.contains("raise --packages"),
                        "capacity error must point at --packages: {e}"
                    );
                    println!("  {model:>3} packages 1  : does not fit (needs >= 2 packages)");
                    prev_tps = Some(0.0);
                    scale_points.push(json::obj(vec![
                        ("model", json::s(model)),
                        ("packages", json::num(packages as f64)),
                        ("fits", Json::Bool(false)),
                        ("error", json::s(e)),
                        ("tokens_per_s", json::num(0.0)),
                    ]));
                }
                Ok((m, p)) => {
                    assert_eq!(
                        m.requests.len() + m.shed_count() + m.failed_count(),
                        SCALE_REQUESTS,
                        "scale-out point must conserve requests ({model}, {packages})"
                    );
                    let tps = m.throughput_tokens_per_s();
                    let ttft = m.summary(LatencyKind::Ttft);
                    let tpot = m.summary(LatencyKind::PerToken);
                    let total = m.summary(LatencyKind::Total);
                    // Schedule-derived output only — the packages=1 row
                    // must reproduce the fabric-off baseline's sub-object
                    // byte for byte.
                    let metrics_json = json::obj(vec![
                        ("completed", json::num(m.requests.len() as f64)),
                        ("shed", json::num(m.shed_count() as f64)),
                        ("failed", json::num(m.failed_count() as f64)),
                        ("total_tokens", json::num(m.total_tokens as f64)),
                        ("wall_s", json::num(m.wall_s)),
                        ("tokens_per_s", json::num(tps)),
                        ("ttft", ttft.json()),
                        ("tpot", tpot.json()),
                        ("total", total.json()),
                    ]);
                    let rendered = metrics_json.to_string();
                    match packages {
                        0 => {
                            baseline = Some(rendered);
                            println!(
                                "  {model:>3} fabric off  : {tps:>8.1} tokens/s   \
                                 {} stage set(s)",
                                p.stage_sets,
                            );
                        }
                        1 => {
                            let same = baseline.as_deref() == Some(rendered.as_str());
                            scale_identity_ok &= same;
                            assert!(
                                same,
                                "{model}: packages=1 must be byte-identical to fabric-off"
                            );
                            prev_tps = Some(tps);
                            println!(
                                "  {model:>3} packages 1  : {tps:>8.1} tokens/s   \
                                 identical to fabric-off"
                            );
                        }
                        _ => {
                            let pt = prev_tps.expect("package rows ascend from 1");
                            assert!(
                                tps > pt,
                                "{model}: throughput must rise with packages \
                                 ({packages}: {tps:.1} vs {pt:.1})"
                            );
                            assert!(
                                tps >= 1.5 * pt,
                                "{model}: each package doubling must scale >= 1.5x \
                                 ({packages}: {tps:.1} vs {pt:.1})"
                            );
                            prev_tps = Some(tps);
                            println!(
                                "  {model:>3} packages {packages}  : {tps:>8.1} tokens/s   \
                                 {} stage set(s), {} fabric hops ({} cycles)",
                                p.stage_sets, p.fabric_hops, p.fabric_hop_cycles,
                            );
                        }
                    }
                    scale_points.push(json::obj(vec![
                        ("model", json::s(model)),
                        ("packages", json::num(packages as f64)),
                        ("fits", Json::Bool(true)),
                        ("stage_sets", json::num(p.stage_sets as f64)),
                        ("fabric_hops", json::num(p.fabric_hops as f64)),
                        ("fabric_hop_cycles", json::num(p.fabric_hop_cycles as f64)),
                        ("tokens_per_s", json::num(tps)),
                        ("metrics", metrics_json),
                    ]));
                }
            }
        }
    }

    let n_points = points.len();
    let n_spec = spec_points.len();
    let n_tenancy = tenancy_points.len();
    let n_open = open_points.len();
    let n_faults = fault_points.len();
    let n_kv = kv_points.len();
    let n_scale = scale_points.len();
    let doc = json::obj(vec![
        ("schema", json::num(7.0)),
        ("model", json::s(MODEL)),
        ("prompt_len", json::num(PROMPT as f64)),
        ("gen_len", json::num(GEN as f64)),
        ("points", Json::Arr(points)),
        (
            "spec",
            json::obj(vec![
                ("batch", json::num(SPEC_BATCH as f64)),
                ("draft_len", json::num(SPEC_DRAFT_LEN as f64)),
                ("draft_cost_ratio", json::num(SPEC_COST_RATIO)),
                ("reference_tokens_per_s", json::num(reference_tps)),
                ("points", Json::Arr(spec_points)),
            ]),
        ),
        (
            "tenancy",
            json::obj(vec![
                ("requests", json::num(TENANT_REQUESTS as f64)),
                ("points", Json::Arr(tenancy_points)),
            ]),
        ),
        (
            "open_loop",
            json::obj(vec![
                ("seed", json::num(OPEN_SEED as f64)),
                ("requests_per_point", json::num(OPEN_SWEEP_REQUESTS as f64)),
                ("capacity_tokens_per_s", json::num(capacity_tps)),
                ("mean_gen_tokens", json::num(mean_gen)),
                (
                    "parity",
                    json::obj(vec![
                        ("closed_tokens_per_s", json::num(closed.throughput_tokens_per_s())),
                        ("open_tokens_per_s", json::num(parity.throughput_tokens_per_s())),
                        ("ratio", json::num(parity_ratio)),
                    ]),
                ),
                ("points", Json::Arr(open_points)),
            ]),
        ),
        (
            "faults",
            json::obj(vec![
                ("seed", json::num(FAULT_SEED as f64)),
                ("identity_ok", Json::Bool(identity_ok)),
                ("determinism_ok", Json::Bool(determinism_ok)),
                (
                    "storm",
                    json::obj(vec![
                        ("kill_tiles", json::num(FAULT_STORM_TILES as f64)),
                        ("enqueued", json::num(SPEC_BATCH as f64)),
                        ("completed", json::num(storm_m.requests.len() as f64)),
                        ("shed", json::num(storm_m.shed_count() as f64)),
                        ("failed", json::num(storm_m.failed_count() as f64)),
                        ("conserved", Json::Bool(storm_conserved)),
                        ("dead_tiles", json::num(storm_p.dead_tiles as f64)),
                        ("job_replays", json::num(storm_p.job_replays as f64)),
                    ]),
                ),
                ("points", Json::Arr(fault_points)),
            ]),
        ),
        (
            "kv_reuse",
            json::obj(vec![
                ("pool_tokens", json::num(KV_POOL_TOKENS as f64)),
                (
                    "block_tokens",
                    json::num(KvReuseConfig::default().block_tokens as f64),
                ),
                (
                    "prefixes",
                    json::num(KvReuseConfig::default().prefixes as f64),
                ),
                (
                    "prefix_len",
                    json::num(KvReuseConfig::default().prefix_len as f64),
                ),
                ("requests_per_point", json::num(KV_SWEEP_REQUESTS as f64)),
                ("identity_ok", Json::Bool(kv_identity_ok)),
                ("points", Json::Arr(kv_points)),
            ]),
        ),
        (
            "scale_out",
            json::obj(vec![
                ("requests_per_point", json::num(SCALE_REQUESTS as f64)),
                ("max_batch", json::num(SCALE_MAX_BATCH as f64)),
                (
                    "package_tiles",
                    json::num(FabricConfig::default().package.tiles as f64),
                ),
                ("identity_ok", Json::Bool(scale_identity_ok)),
                ("points", Json::Arr(scale_points)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_serving.json", format!("{doc}\n")).expect("write serving report");
    println!(
        "\nwrote BENCH_serving.json ({n_points} batch points, {n_spec} spec points, \
         {n_tenancy} tenancy points, {n_open} open-loop points, {n_faults} fault points, \
         {n_kv} kv-reuse points, {n_scale} scale-out points)"
    );
}
