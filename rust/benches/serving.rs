//! Bench: the event-driven pipeline-parallel serving stack — simulated
//! decode throughput vs. batch size at a fixed model, plus host-side
//! timing of the scheduler itself. Dumps `BENCH_serving.json`
//! (`{"schema": 1, "model", "prompt_len", "gen_len", "points": [...]}`,
//! one point per batch size with simulated tokens/s, the serialized PR-2
//! reference, TTFT and p99) so the pipelining win stays machine-diffable
//! across PRs (CI validates batch-8 > 2× batch-1 and archives the file).
//! Run: `cargo bench --bench serving`

mod harness;

use picnic::config::PicnicConfig;
use picnic::coordinator::{serialized_workload_cycles, BatchPolicy, Metrics, Server, ServerConfig};
use picnic::models::LlamaConfig;
use picnic::sim::AnalyticSim;
use picnic::util::json::{self, Json};

const MODEL: &str = "1b";
const PROMPT: usize = 256;
const GEN: usize = 32;

fn run_once(batch: usize) -> Metrics {
    let mut s = Server::new(ServerConfig {
        picnic: PicnicConfig::default(),
        model: LlamaConfig::by_name(MODEL).expect("model"),
        policy: BatchPolicy {
            max_batch: batch.max(1),
            kv_budget: 1 << 22,
            ..BatchPolicy::default()
        },
    });
    for _ in 0..batch {
        s.submit(PROMPT, GEN).expect("submit");
    }
    s.run_to_completion().expect("run");
    s.metrics.clone()
}

fn main() {
    harness::section("pipeline-parallel serving: throughput vs batch size");
    let cfg = PicnicConfig::default();
    let model = LlamaConfig::by_name(MODEL).expect("model");
    let sim = AnalyticSim::new(cfg.clone());
    let freq = cfg.system.frequency_hz;
    let chunk = BatchPolicy::default().prefill_chunk;

    let batches = [1usize, 2, 4, 8];
    let mut points: Vec<Json> = Vec::new();
    for &batch in &batches {
        harness::bench(&format!("serve/{MODEL}_batch{batch}"), 1, 3, || {
            let m = run_once(batch);
            assert_eq!(m.requests.len(), batch);
        });
        let m = run_once(batch);

        // serialized PR-2 reference: the same jobs, each monopolizing the
        // whole fabric back to back
        let serialized =
            serialized_workload_cycles(&sim, &cfg, &model, batch, PROMPT, GEN, chunk)
                .expect("plan");
        let ser_tps = m.total_tokens as f64 / (serialized as f64 / freq);
        println!(
            "  batch {batch}: {:>8.1} tokens/s pipelined   {:>8.1} tokens/s serialized   \
             mean TTFT {:.3} ms   p99 {:.3} ms",
            m.throughput_tokens_per_s(),
            ser_tps,
            1e3 * m.mean_ttft_s(),
            1e3 * m.p99_total_s(),
        );
        points.push(json::obj(vec![
            ("batch", json::num(batch as f64)),
            ("tokens_per_s", json::num(m.throughput_tokens_per_s())),
            ("serialized_tokens_per_s", json::num(ser_tps)),
            ("mean_ttft_s", json::num(m.mean_ttft_s())),
            ("p99_total_s", json::num(m.p99_total_s())),
        ]));
    }

    let n_points = points.len();
    let doc = json::obj(vec![
        ("schema", json::num(1.0)),
        ("model", json::s(MODEL)),
        ("prompt_len", json::num(PROMPT as f64)),
        ("gen_len", json::num(GEN as f64)),
        ("points", Json::Arr(points)),
    ]);
    std::fs::write("BENCH_serving.json", format!("{doc}\n")).expect("write serving report");
    println!("\nwrote BENCH_serving.json ({n_points} batch points)");
}
