//! Bench: the L3 hot paths in isolation — detailed mesh cycle stepping,
//! crossbar SMAC, SCU rows, plan building, and the analytic phase walker.
//! This is the profile target for the EXPERIMENTS.md §Perf iteration log
//! (repo root); results are also dumped to `BENCH_hotpath.json` so every
//! PR's numbers are machine-diffable (CI archives the file).
//! Run: `cargo bench --bench hotpath`

mod harness;

use picnic::config::{PicnicConfig, SystemConfig};
use picnic::isa::Assembler;
use picnic::mapper::ScheduleBuilder;
use picnic::models::LlamaConfig;
use picnic::pe::{Crossbar, QuantSpec};
use picnic::scu::Scu;
use picnic::sim::{AnalyticSim, TileEngine};
use picnic::util::Rng;

fn main() {
    harness::section("L3 hot paths");

    // 1. Detailed mesh cycle stepping: 16×16 mesh, pipeline program.
    {
        let cfg = SystemConfig::tiny(16);
        let mut eng = TileEngine::new(cfg, 128);
        let mut asm = Assembler::new(16);
        for r in 0..16 {
            asm.pipeline_east(r, 1024);
        }
        let prog = asm.finish();
        eng.load_program(&prog);
        for r in 0..16 {
            eng.mesh.inject(r * 16, picnic::isa::Port::West, 1.0);
        }
        let mut cycles_done = 0u64;
        harness::bench("engine/mesh16_step_1k_cycles", 1, 10, || {
            // re-load so every iteration does identical work
            eng.load_program(&prog);
            cycles_done += eng.run(1024);
        });
        let total_router_cycles = 10 * 1024u64 * 256;
        println!("  (≈{total_router_cycles} router-cycles exercised)");
    }

    // 2. Crossbar SMAC 256×256.
    {
        let mut rng = Rng::seed_from_u64(1);
        let w: Vec<f32> = (0..256 * 256).map(|_| rng.sym_f32(0.05)).collect();
        let mut xb = Crossbar::program(&w, 256, 256, QuantSpec::default());
        let cal: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..256).map(|_| rng.sym_f32(1.0)).collect())
            .collect();
        xb.calibrate(&cal);
        let x: Vec<f32> = (0..256).map(|_| rng.sym_f32(1.0)).collect();
        let mut y: Vec<f32> = Vec::with_capacity(256);
        harness::bench("pe/smac_256x256", 10, 200, || {
            xb.smac_into(&x, &mut y);
            assert_eq!(y.len(), 256);
        });
    }

    // 3. SCU softmax row of 2048.
    {
        let mut rng = Rng::seed_from_u64(2);
        let row: Vec<f32> = (0..2048).map(|_| rng.sym_f32(4.0)).collect();
        let mut scu = Scu::new();
        let mut out: Vec<f32> = Vec::with_capacity(2048);
        harness::bench("scu/softmax_row_2048", 10, 200, || {
            scu.softmax_row_into(&row, &mut out);
            assert_eq!(out.len(), 2048);
        });
    }

    // 4. Plan building (mapper) for one 8B attention layer.
    {
        let cfg = PicnicConfig::default();
        let model = LlamaConfig::llama3_8b();
        let b = ScheduleBuilder::new(&cfg, &model);
        let layers = model.layers();
        harness::bench("mapper/plan_8b_attention", 5, 50, || {
            let p = b.plan_layer(&layers[0], 1, 2048).expect("plan");
            assert!(!p.phases.is_empty());
        });
    }

    // 5. Full analytic run, 8B 512/512.
    {
        let sim = AnalyticSim::new(PicnicConfig::default());
        let model = LlamaConfig::llama3_8b();
        harness::bench("analytic/run_8b_512", 1, 5, || {
            let r = sim
                .run(&model, &picnic::models::Workload::new(512, 512))
                .expect("run");
            assert!(r.stats.tokens_per_s > 0.0);
        });
    }

    harness::write_json("BENCH_hotpath.json");
}
