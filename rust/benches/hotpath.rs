//! Bench: the L3 hot paths in isolation — detailed mesh cycle stepping,
//! crossbar SMAC, SCU rows, plan building, and the analytic phase walker —
//! plus the deterministic parallel regions (multi-row SMAC, engine
//! sweeps) at 1 vs 4 workers with byte-identity asserted between them.
//! This is the profile target for the EXPERIMENTS.md §Perf iteration log
//! (repo root); results are also dumped to `BENCH_hotpath.json` so every
//! PR's numbers are machine-diffable (CI archives the file).
//! Run: `cargo bench --bench hotpath`

mod harness;

use picnic::config::{PicnicConfig, SystemConfig};
use picnic::isa::Assembler;
use picnic::mapper::ScheduleBuilder;
use picnic::models::LlamaConfig;
use picnic::pe::{Crossbar, QuantSpec};
use picnic::scu::Scu;
use picnic::sim::{AnalyticSim, TileEngine};
use picnic::util::{Pool, Rng};

/// Build the 16×16 pipeline engine used by the mesh benches.
fn mesh16_engine() -> (TileEngine, picnic::isa::Program) {
    let cfg = SystemConfig::tiny(16);
    let mut eng = TileEngine::new(cfg, 128);
    let mut asm = Assembler::new(16);
    for r in 0..16 {
        asm.pipeline_east(r, 1024);
    }
    let prog = asm.finish();
    eng.load_program(&prog);
    for r in 0..16 {
        eng.mesh.inject(r * 16, picnic::isa::Port::West, 1.0);
    }
    (eng, prog)
}

fn main() {
    harness::section("L3 hot paths");

    // 1. Detailed mesh cycle stepping: 16×16 mesh, pipeline program.
    {
        let (mut eng, prog) = mesh16_engine();
        let mut cycles_done = 0u64;
        harness::bench_elems("engine/mesh16_step_1k_cycles", 1, 10, 1024 * 256, || {
            // re-load so every iteration does identical work
            eng.load_program(&prog);
            cycles_done += eng.run(1024);
        });
        let total_router_cycles = 10 * 1024u64 * 256;
        println!("  (≈{total_router_cycles} router-cycles exercised)");
    }

    // 2. Crossbar SMAC 256×256.
    {
        let mut rng = Rng::seed_from_u64(1);
        let w: Vec<f32> = (0..256 * 256).map(|_| rng.sym_f32(0.05)).collect();
        let mut xb = Crossbar::program(&w, 256, 256, QuantSpec::default());
        let cal: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..256).map(|_| rng.sym_f32(1.0)).collect())
            .collect();
        xb.calibrate(&cal);
        let x: Vec<f32> = (0..256).map(|_| rng.sym_f32(1.0)).collect();
        let mut y: Vec<f32> = Vec::with_capacity(256);
        harness::bench_elems("pe/smac_256x256", 10, 200, 256 * 256, || {
            xb.smac_into(&x, &mut y);
            assert_eq!(y.len(), 256);
        });
    }

    // 3. SCU softmax row of 2048.
    {
        let mut rng = Rng::seed_from_u64(2);
        let row: Vec<f32> = (0..2048).map(|_| rng.sym_f32(4.0)).collect();
        let mut scu = Scu::new();
        let mut out: Vec<f32> = Vec::with_capacity(2048);
        harness::bench_elems("scu/softmax_row_2048", 10, 200, 2048, || {
            scu.softmax_row_into(&row, &mut out);
            assert_eq!(out.len(), 2048);
        });
    }

    // 4. Plan building (mapper) for one 8B attention layer.
    {
        let cfg = PicnicConfig::default();
        let model = LlamaConfig::llama3_8b();
        let b = ScheduleBuilder::new(&cfg, &model);
        let layers = model.layers();
        harness::bench("mapper/plan_8b_attention", 5, 50, || {
            let p = b.plan_layer(&layers[0], 1, 2048).expect("plan");
            assert!(!p.phases.is_empty());
        });
    }

    // 5. Full analytic run, 8B 512/512.
    {
        let sim = AnalyticSim::new(PicnicConfig::default());
        let model = LlamaConfig::llama3_8b();
        harness::bench("analytic/run_8b_512", 1, 5, || {
            let r = sim
                .run(&model, &picnic::models::Workload::new(512, 512))
                .expect("run");
            assert!(r.stats.tokens_per_s > 0.0);
        });
    }

    harness::section("parallel regions (1 vs 4 workers, byte-identical)");

    // 6. Multi-row crossbar SMAC: 1024×2048 = 2M MAC slots — above the
    //    PAR_MAC_MIN threshold, so the column-block parallel kernel
    //    engages at >1 worker. The t1/t4 outputs are asserted
    //    bit-identical before timing (the pool's determinism contract).
    {
        let (rows, cols) = (1024usize, 2048usize);
        let mut rng = Rng::seed_from_u64(3);
        let w: Vec<f32> = (0..rows * cols).map(|_| rng.sym_f32(0.05)).collect();
        let mut xb = Crossbar::program(&w, rows, cols, QuantSpec::default());
        let cal: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..rows).map(|_| rng.sym_f32(1.0)).collect())
            .collect();
        xb.calibrate(&cal);
        let x: Vec<f32> = (0..rows).map(|_| rng.sym_f32(1.0)).collect();
        let (p1, p4) = (Pool::new(1), Pool::new(4));
        let mut y1: Vec<f32> = Vec::with_capacity(cols);
        let mut y4: Vec<f32> = Vec::with_capacity(cols);
        xb.smac_into_with(p1, &x, &mut y1);
        xb.smac_into_with(p4, &x, &mut y4);
        assert!(
            y1.iter().zip(y4.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "parallel SMAC must be byte-identical to sequential"
        );
        let elems = (rows * cols) as u64;
        harness::bench_elems("pe/smac_1024x2048_t1", 3, 20, elems, || {
            xb.smac_into_with(p1, &x, &mut y1);
        });
        harness::bench_elems("pe/smac_1024x2048_t4", 3, 20, elems, || {
            xb.smac_into_with(p4, &x, &mut y4);
        });
    }

    // 7. Engine sweep: 8 independent 16×16 engines, 256 cycles each —
    //    the embarrassingly-parallel shape of the bench sweeps and
    //    calibration probes. Per-point cycle counts are asserted equal
    //    across pools (each engine itself runs sequentially; only the
    //    sweep fans out).
    {
        let sweep = |pool: Pool| -> Vec<u64> {
            pool.par_map_index(8, |_| {
                let (eng, _) = mesh16_engine();
                let mut eng = eng.with_pool(Pool::sequential());
                eng.run(256)
            })
        };
        let (p1, p4) = (Pool::new(1), Pool::new(4));
        let c1 = sweep(p1);
        let c4 = sweep(p4);
        assert_eq!(c1, c4, "sweep cycle counts must be pool-invariant");
        let elems = 8 * 256 * 256u64;
        harness::bench_elems("engine/mesh16_sweep8_t1", 1, 10, elems, || {
            let c = sweep(p1);
            assert_eq!(c, c1);
        });
        harness::bench_elems("engine/mesh16_sweep8_t4", 1, 10, elems, || {
            let c = sweep(p4);
            assert_eq!(c, c4);
        });
    }

    harness::write_json("BENCH_hotpath.json");
}
