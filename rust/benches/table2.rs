//! Bench: regenerate Table II (PICNIC throughput/power/efficiency for
//! 3 models × 3 context lengths, no CCPG) and time the simulation.
//! Run: `cargo bench --bench table2`

mod harness;

use picnic::config::PicnicConfig;
use picnic::report;

fn main() {
    let cfg = PicnicConfig::default();
    harness::section("Table II — LLM inference benchmark (no CCPG)");
    let mut rows = None;
    harness::bench("table2/full_sweep", 1, 3, || {
        rows = Some(report::table2(&cfg).expect("table2"));
    });
    println!("\n{}", report::tables::render_table2(&rows.unwrap()));
}
