//! Bench: regenerate Table III (cross-platform comparison, Llama-8B
//! 1024/1024, H100 baseline, PICNIC with CCPG).
//! Run: `cargo bench --bench table3`

mod harness;

use picnic::config::PicnicConfig;
use picnic::report;

fn main() {
    let cfg = PicnicConfig::default();
    harness::section("Table III — comparison with other platforms");
    let mut rows = None;
    harness::bench("table3/picnic_8b_ccpg", 1, 3, || {
        rows = Some(report::table3(&cfg).expect("table3"));
    });
    println!("\n{}", report::tables::render_table3(&rows.unwrap()));
}
