//! Bench: regenerate Table IV (power & area breakdown per unit router-PE
//! macro). Run: `cargo bench --bench table4`

mod harness;

use picnic::config::PicnicConfig;
use picnic::report;

fn main() {
    let cfg = PicnicConfig::default();
    harness::section("Table IV — power & area breakdown");
    let mut b = None;
    harness::bench("table4/breakdown", 10, 100, || {
        b = Some(report::table4(&cfg));
    });
    println!("\n{}", report::tables::render_table4(&b.unwrap()));
}
