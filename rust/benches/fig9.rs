//! Bench: regenerate Fig 9 (average C2C transfer power, electrical vs
//! optical, per model × context). Run: `cargo bench --bench fig9`

mod harness;

use picnic::config::PicnicConfig;
use picnic::report;

fn main() {
    let cfg = PicnicConfig::default();
    harness::section("Fig 9 — C2C power, electrical vs optical");
    let mut rows = None;
    harness::bench("fig9/link_sweep", 1, 2, || {
        rows = Some(report::fig9(&cfg).expect("fig9"));
    });
    println!("\n{}", report::figures::render_fig9(&rows.unwrap()));
}
