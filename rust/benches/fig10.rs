//! Bench: regenerate Fig 10 (C2C transfer distribution over time,
//! Llama 3.2-1B). Run: `cargo bench --bench fig10`

mod harness;

use picnic::config::PicnicConfig;
use picnic::report;

fn main() {
    let cfg = PicnicConfig::default();
    harness::section("Fig 10 — C2C transfer distribution over time");
    let mut f = None;
    harness::bench("fig10/trace", 1, 3, || {
        f = Some(report::fig10(&cfg, 80).expect("fig10"));
    });
    println!("\n{}", report::figures::render_fig10(&f.unwrap()));
}
