//! Bench: regenerate Fig 8 (system power & efficiency with vs without
//! CCPG per model). Run: `cargo bench --bench fig8`

mod harness;

use picnic::config::PicnicConfig;
use picnic::report;

fn main() {
    let cfg = PicnicConfig::default();
    harness::section("Fig 8 — CCPG power & efficiency comparison");
    let mut rows = None;
    harness::bench("fig8/ccpg_sweep", 1, 3, || {
        rows = Some(report::fig8(&cfg).expect("fig8"));
    });
    println!("\n{}", report::figures::render_fig8(&rows.unwrap()));
}
