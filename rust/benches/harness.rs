//! Shared micro-bench harness (replaces criterion in this offline build):
//! warm-up, N timed iterations, mean/min/max report. Each bench binary
//! (`harness = false`) regenerates one paper table/figure and times the
//! underlying simulation so regressions in the hot path are visible.
//!
//! Every `bench()` result is also recorded in-process; a bench binary can
//! call [`write_json`] before exiting to dump a machine-readable
//! `BENCH_<name>.json` report (name → mean/min/max seconds, iters, and —
//! for benches declaring a work size via [`bench_elems`] — a derived
//! `elems_per_sec` throughput) so the perf trajectory stays diffable
//! across PRs (CI archives the artifact).

// Included via `mod harness;` by every bench binary; not every bench uses
// every helper, and the standalone compile-check target uses none of them.
#![allow(dead_code)]

use picnic::util::json::{self, Json};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// One recorded `bench()` run.
struct Record {
    name: String,
    mean_s: f64,
    min_s: f64,
    max_s: f64,
    iters: usize,
    /// Elements of work per iteration (0 = not declared; no throughput
    /// row is derived).
    elems: u64,
}

static RECORDS: Mutex<Vec<Record>> = Mutex::new(Vec::new());

/// Time `f` over `iters` iterations after `warmup` untimed ones; prints a
/// criterion-style line, records the result for [`write_json`], and
/// returns the mean seconds per iteration.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> f64 {
    bench_elems(name, warmup, iters, 0, f)
}

/// [`bench`] with a declared per-iteration work size: `elems` is whatever
/// unit makes the bench comparable across shapes (MAC slots, router-cycles,
/// row elements). The JSON report derives `elems_per_sec = elems / mean_s`
/// so throughput — not just latency — stays diffable across PRs.
pub fn bench_elems<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    elems: u64,
    mut f: F,
) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    // Clamp: the true mean lies in [min, max], but summation rounding can
    // push it a ulp outside, which would trip the CI report validator.
    let mean = (samples.iter().sum::<f64>() / samples.len() as f64).clamp(min, max);
    println!(
        "bench {name:<40} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
    RECORDS.lock().unwrap().push(Record {
        name: name.to_string(),
        mean_s: mean,
        min_s: min,
        max_s: max,
        iters,
        elems,
    });
    mean
}

/// Pretty separator for bench output sections.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Dump every recorded `bench()` result to `path` as JSON:
/// `{"schema": 2, "host_cpus": N, "benches": {name: {mean_s, min_s,
/// max_s, iters[, elems, elems_per_sec]}}}`. `host_cpus` records the
/// machine's available parallelism so downstream gates on parallel
/// speedups can skip hosts too small to show one. Called by a bench
/// binary's `main` after its last bench.
pub fn write_json(path: &str) {
    let records = RECORDS.lock().unwrap();
    let benches: BTreeMap<String, Json> = records
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("mean_s", json::num(r.mean_s)),
                ("min_s", json::num(r.min_s)),
                ("max_s", json::num(r.max_s)),
                ("iters", json::num(r.iters as f64)),
            ];
            if r.elems > 0 {
                fields.push(("elems", json::num(r.elems as f64)));
                // Floor the divisor: a sub-resolution mean would print as
                // `inf`, which is not valid JSON.
                fields.push(("elems_per_sec", json::num(r.elems as f64 / r.mean_s.max(1e-12))));
            }
            (r.name.clone(), json::obj(fields))
        })
        .collect();
    let host_cpus = std::thread::available_parallelism().map_or(1, usize::from);
    let doc = json::obj(vec![
        ("schema", json::num(2.0)),
        ("host_cpus", json::num(host_cpus as f64)),
        ("benches", Json::Obj(benches)),
    ]);
    std::fs::write(path, format!("{doc}\n")).expect("write bench report");
    println!("\nwrote {path} ({} benches)", records.len());
}
