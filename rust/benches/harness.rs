//! Shared micro-bench harness (replaces criterion in this offline build):
//! warm-up, N timed iterations, mean/min/max report. Each bench binary
//! (`harness = false`) regenerates one paper table/figure and times the
//! underlying simulation so regressions in the hot path are visible.

// Included via `mod harness;` by every bench binary; not every bench uses
// every helper, and the standalone compile-check target uses none of them.
#![allow(dead_code)]

use std::time::Instant;

/// Time `f` over `iters` iterations after `warmup` untimed ones; prints a
/// criterion-style line and returns the mean seconds per iteration.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "bench {name:<40} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
    mean
}

/// Pretty separator for bench output sections.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
