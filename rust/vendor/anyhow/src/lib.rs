//! Minimal offline stand-in for the crates.io `anyhow` crate.
//!
//! The PICNIC workspace builds without network access, so this in-tree
//! crate provides exactly the surface the workspace uses — an opaque
//! [`Error`] type, the [`Result`] alias, and the `anyhow!` / `bail!` /
//! `ensure!` macros — with no transitive dependencies. Like the real
//! crate, `Error` converts from any `std::error::Error` via `?`, renders
//! its source chain under the `{:#}` alternate format, and deliberately
//! does **not** implement `std::error::Error` itself (that is what keeps
//! the blanket `From` impl coherent).

use std::error::Error as StdError;
use std::fmt;

/// An opaque boxed error with a source chain.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// A plain-message error (what `anyhow!("...")` produces).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Construct an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            inner: Box::new(MessageError(message.to_string())),
        }
    }

    /// The lowest-level cause in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cause: &(dyn StdError + 'static) = self.inner.as_ref();
        while let Some(next) = cause.source() {
            cause = next;
        }
        cause
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)?;
        if f.alternate() {
            let mut source = self.inner.source();
            while let Some(s) = source {
                write!(f, ": {s}")?;
                source = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            $crate::bail!($($tt)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_and_double(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?;
        ensure!(n < 1000, "{n} too large");
        Ok(n * 2)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_and_double("21").unwrap(), 42);
        let e = parse_and_double("nope").unwrap_err();
        assert!(e.to_string().contains("invalid digit"));
    }

    #[test]
    fn ensure_and_bail_format() {
        let e = parse_and_double("1001").unwrap_err();
        assert_eq!(e.to_string(), "1001 too large");
    }

    #[test]
    fn anyhow_macro_formats() {
        let what = "table9";
        let e: Error = anyhow!("unknown report {what}");
        assert_eq!(format!("{e}"), "unknown report table9");
        assert_eq!(format!("{e:#}"), "unknown report table9");
    }

    #[test]
    fn alternate_display_walks_source_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing file");
        let e: Error = io.into();
        assert!(format!("{e:#}").contains("missing file"));
        assert_eq!(e.root_cause().to_string(), "missing file");
    }
}
