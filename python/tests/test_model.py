"""L2 model tests: decoder block shapes, float-vs-quant error bounds, and
AOT lowering round-trip (HLO text parses and contains no custom-calls)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    return model.init_params(model.TINY, seed=0)


@pytest.fixture(scope="module")
def x():
    return 0.5 * jax.random.normal(
        jax.random.PRNGKey(99), (model.TINY.seq, model.TINY.d_model), jnp.float32
    )


class TestDecoderFloat:
    def test_shape(self, x, params):
        y = model.decoder_block_float(x, params, model.TINY)
        assert y.shape == x.shape

    def test_matches_pure_ref(self, x, params):
        """The pallas-kernel decoder must equal a decoder built only from
        ref.py pieces — validates the L2 wiring, not just the kernels."""
        y = model.decoder_block_float(x, params, model.TINY)

        h = ref.rmsnorm(x, params["g_attn"])
        q = model._split_heads(h @ params["wq"], model.TINY.n_heads)
        k = model._split_heads(h @ params["wk"], model.TINY.n_heads)
        v = model._split_heads(h @ params["wv"], model.TINY.n_heads)
        att = x + model._merge_heads(ref.mha(q, k, v)) @ params["wo"]
        want = model.ffn_block(att, params)
        np.testing.assert_allclose(y, want, rtol=3e-5, atol=3e-5)

    def test_residual_identity_with_zero_weights(self, x):
        p = {k: jnp.zeros_like(v) for k, v in model.init_params(model.TINY).items()}
        p["g_attn"] = jnp.ones_like(p["g_attn"])
        p["g_ffn"] = jnp.ones_like(p["g_ffn"])
        y = model.decoder_block_float(x, p, model.TINY)
        np.testing.assert_allclose(y, x, atol=1e-6)

    def test_causality(self, params):
        """Perturbing a late token must not change earlier outputs."""
        cfg = model.TINY
        x1 = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (cfg.seq, cfg.d_model))
        x2 = x1.at[-1].add(10.0)
        y1 = model.decoder_block_float(x1, params, cfg)
        y2 = model.decoder_block_float(x2, params, cfg)
        np.testing.assert_allclose(y1[:-1], y2[:-1], atol=1e-5)
        assert not np.allclose(y1[-1], y2[-1])


class TestDecoderQuant:
    def test_tracks_float_path(self, x, params):
        """The quantized (SMAC + PWL softmax) decoder must track the float
        decoder within the calibrated error bound — the same bound the rust
        functional simulator is held to."""
        yf = model.decoder_block_float(x, params, model.TINY)
        yq = model.decoder_block_quant(x, params, model.TINY)
        rel = np.linalg.norm(yq - yf) / np.linalg.norm(yf)
        assert rel < 0.05, f"quant path rel err {rel}"

    def test_error_decreases_with_adc_bits(self, x, params):
        errs = []
        for bits in (6, 8, 12):
            ya = model.attention_block_quant(x, params, model.TINY, adc_bits=bits)
            yf = model.attention_block_float(x, params, model.TINY)
            errs.append(float(np.linalg.norm(ya - yf) / np.linalg.norm(yf)))
        assert errs[0] >= errs[1] >= errs[2] - 1e-6, errs


class TestAotLowering:
    @pytest.mark.parametrize("fn_name,n_args", [
        ("decoder_float_flat", 1 + len(model.PARAM_ORDER)),
        ("attention_float_flat", 3),
        ("softmax_pwl_flat", 1),
    ])
    def test_lowers_to_custom_call_free_hlo(self, fn_name, n_args):
        from compile.aot import to_hlo_text

        cfg = model.TINY
        spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
        params = model.init_params(cfg)
        arg_specs = {
            "decoder_float_flat": (
                spec(cfg.seq, cfg.d_model),
                *(jax.ShapeDtypeStruct(params[k].shape, jnp.float32)
                  for k in model.PARAM_ORDER),
            ),
            "attention_float_flat": (spec(cfg.n_heads, cfg.seq, cfg.d_head),) * 3,
            "softmax_pwl_flat": (spec(32, 64),),
        }[fn_name]
        assert len(arg_specs) == n_args
        fn = getattr(model, fn_name)
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        assert "ENTRY" in text
        # interpret=True pallas must lower to plain HLO the CPU client can run
        assert "custom-call" not in text.lower(), "Mosaic custom-call leaked into HLO"

    def test_flat_wrappers_match_dict_api(self, x, params):
        flat = model.decoder_float_flat(x, *(params[k] for k in model.PARAM_ORDER))[0]
        want = model.decoder_block_float(x, params, model.TINY)
        np.testing.assert_allclose(flat, want, atol=1e-6)
