"""IPCN Python toolchain tests + golden vectors shared with the rust
assembler (rust/tests/test_toolchain_crosscheck.rs loads the same program
and asserts an identical hex encoding)."""

import pytest

from compile.ipcn_api import (
    IDLE,
    Instr,
    IntXfer,
    Mode,
    Port,
    ProgramBuilder,
    port_mask,
)

# The shared golden program: dim=4, three rows. Any change here must be
# mirrored in rust/tests/test_toolchain_crosscheck.rs.
def golden_program() -> ProgramBuilder:
    b = ProgramBuilder(4)
    b.pipeline_east(0, 16)
    dmac = Instr(rd_en=port_mask([Port.NORTH, Port.WEST]), mode=Mode.DMAC)
    psum = Instr(
        rd_en=port_mask([Port.NORTH, Port.SOUTH]),
        mode=Mode.PARTIAL_SUM,
        out_en=port_mask([Port.PE]),
    )
    b.row([((1, 0, 1, 3), dmac), ((2, 0, 2, 3), psum)], repeat=8)
    spw = Instr(
        rd_en=port_mask([Port.WEST]),
        mode=Mode.SP_WRITE,
        intxfer=IntXfer.FIFO_TO_SP,
        sp_addr=0x2A,
    )
    b.row([((3, 1, 3, 2), spw)], repeat=2)
    return b


GOLDEN_HEX_PATH = "tests/golden_ipcn_program.hex"


class TestEncoding:
    def test_idle_is_zero(self):
        assert IDLE.encode() == 0

    def test_field_packing(self):
        i = Instr(rd_en=0b1000, mode=Mode.ROUTE, out_en=0b0010,
                  intxfer=IntXfer.NONE, sp_addr=0)
        # rd_en=West(3)<<23 | mode=1<<19 | out_en=East(1)<<12
        assert i.encode() == (0b1000 << 23) | (1 << 19) | (0b0010 << 12)

    def test_sp_addr_bounds(self):
        with pytest.raises(ValueError):
            Instr(sp_addr=1024).encode()

    def test_port_mask(self):
        assert port_mask([Port.NORTH, Port.DOWN]) == 0b1000001


class TestBuilder:
    def test_max_two_commands_per_row(self):
        b = ProgramBuilder(4)
        i1 = Instr(mode=Mode.ROUTE, rd_en=1, out_en=2)
        i2 = Instr(mode=Mode.DMAC, rd_en=3)
        i3 = Instr(mode=Mode.SP_READ, sp_addr=1)
        with pytest.raises(ValueError):
            b.row([((0, 0, 0, 0), i1), ((1, 0, 1, 0), i2), ((2, 0, 2, 0), i3)])

    def test_overlap_rejected(self):
        b = ProgramBuilder(4)
        i1 = Instr(mode=Mode.ROUTE, rd_en=1, out_en=2)
        with pytest.raises(ValueError):
            b.row([((0, 0, 1, 1), i1), ((1, 1, 2, 2), i1)])

    def test_out_of_bounds_rejected(self):
        b = ProgramBuilder(4)
        with pytest.raises(ValueError):
            b.row([((0, 0, 4, 0), Instr(mode=Mode.ROUTE))])

    def test_hex_shape(self):
        hexfile = golden_program().compile_hex()
        lines = hexfile.strip().splitlines()
        assert len(lines) == 3
        for line in lines:
            cmd1, cmd2, repeat, sel = line.split(";")
            assert len(cmd1) == len(cmd2) == len(repeat) == 8
            assert len(sel) == 8  # 16 routers × 2 bits = 4 bytes = 8 hex
            int(cmd1, 16), int(cmd2, 16), int(repeat, 16), int(sel, 16)

    def test_golden_file_up_to_date(self):
        """The checked-in golden hex must match what the API emits — the
        rust cross-check test reads the same file."""
        import os

        hexfile = golden_program().compile_hex()
        if not os.path.exists(GOLDEN_HEX_PATH):
            with open(GOLDEN_HEX_PATH, "w") as f:
                f.write(hexfile)
        with open(GOLDEN_HEX_PATH) as f:
            assert f.read() == hexfile, (
                "golden_ipcn_program.hex is stale — regenerate by deleting it"
            )
