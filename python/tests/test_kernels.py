"""L1 kernel correctness: pallas vs pure-jnp ref — the core numeric signal.

hypothesis sweeps shapes/dtypes per the rust_pallas hw-codesign guide; every
kernel is asserted allclose against kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention, flash_mha
from compile.kernels.smac import calibrate_full_scale, smac_full, smac_xbar
from compile.kernels.softmax_pwl import softmax_pwl


def rand(key, *shape, dtype=jnp.float32, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("s,d", [(32, 16), (64, 32), (128, 64), (96, 48)])
    def test_matches_ref_causal(self, s, d):
        q, k, v = rand(0, s, d), rand(1, s, d), rand(2, s, d)
        out = flash_attention(q, k, v, block_q=32, block_k=32, causal=True)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("s,d", [(64, 32), (32, 64)])
    def test_matches_ref_non_causal(self, s, d):
        q, k, v = rand(3, s, d), rand(4, s, d), rand(5, s, d)
        out = flash_attention(q, k, v, block_q=32, block_k=32, causal=False)
        want = ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_cross_attention_longer_kv(self):
        # decode-phase shape: few queries, long KV (KV cache)
        q, k, v = rand(6, 32, 16), rand(7, 128, 16), rand(8, 128, 16)
        out = flash_attention(q, k, v, block_q=32, block_k=32, causal=False)
        want = ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_mha_matches_ref(self):
        h, s, d = 4, 64, 16
        q, k, v = rand(9, h, s, d), rand(10, h, s, d), rand(11, h, s, d)
        out = flash_mha(q, k, v)
        want = ref.mha(q, k, v)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    def test_rejects_misaligned_shapes(self):
        q = rand(12, 33, 16)
        with pytest.raises(ValueError):
            flash_attention(q, q, q, block_q=32, block_k=32)

    def test_rows_sum_preserved(self):
        # attention output of constant V must be constant
        q, k = rand(13, 64, 32), rand(14, 64, 32)
        v = jnp.ones((64, 32), jnp.float32) * 3.0
        out = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, 3.0 * jnp.ones_like(out), rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        s_blocks=st.integers(1, 4),
        d=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
        causal=st.booleans(),
    )
    def test_hypothesis_shape_sweep(self, s_blocks, d, seed, causal):
        s = 32 * s_blocks
        q, k, v = rand(seed, s, d), rand(seed + 1, s, d), rand(seed + 2, s, d)
        out = flash_attention(q, k, v, causal=causal)
        want = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)

    def test_numerically_extreme_scores(self):
        # large-magnitude Q/K stress the online-softmax max tracking
        q, k, v = rand(20, 64, 32, scale=30.0), rand(21, 64, 32, scale=30.0), rand(22, 64, 32)
        out = flash_attention(q, k, v, causal=True)
        want = ref.attention(q, k, v, causal=True)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# SMAC crossbar
# ---------------------------------------------------------------------------

class TestSmac:
    def test_single_crossbar_matches_ref(self):
        # k_chunk >= K and calibration set == eval set → identical to ref.smac
        x, w = rand(30, 32, 64, scale=0.5), rand(31, 64, 128, scale=0.02)
        out = smac_full(x, w, k_chunk=64, tile_m=32, tile_n=128)
        want = ref.smac(x, w)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("adc_bits,tol", [(8, 0.05), (10, 0.02), (12, 0.01)])
    def test_approaches_float_with_adc_bits(self, adc_bits, tol):
        x, w = rand(32, 32, 128, scale=0.5), rand(33, 128, 128, scale=0.02)
        out = smac_full(x, w, adc_bits=adc_bits, k_chunk=128, tile_m=32, tile_n=128)
        want = ref.smac_float(x, w)
        rel = np.linalg.norm(out - want) / np.linalg.norm(want)
        assert rel < tol, f"rel err {rel} at {adc_bits} ADC bits"

    def test_multi_crossbar_split(self):
        # K split across two 256-row crossbars, ADC per chunk then digital sum
        x, w = rand(34, 32, 512, scale=0.5), rand(35, 512, 128, scale=0.02)
        out = smac_full(x, w, k_chunk=256, tile_m=32, tile_n=128)
        want = ref.smac_float(x, w)
        rel = np.linalg.norm(out - want) / np.linalg.norm(want)
        assert rel < 0.02

    def test_calibration_full_scale_positive(self):
        xq = jnp.round(rand(36, 16, 256, scale=20.0))
        wq = jnp.round(rand(37, 256, 64, scale=20.0))
        fs = calibrate_full_scale(xq, wq, k_chunk=256)
        assert fs.shape == (1, 64)
        assert (np.asarray(fs) >= 1.0).all()

    def test_xbar_kernel_zero_input(self):
        xq = jnp.zeros((32, 256), jnp.float32)
        wq = jnp.round(rand(38, 256, 128, scale=20.0))
        fs = jnp.ones((1, 128), jnp.float32)
        out = smac_xbar(xq, wq, fs, k_chunk=256, tile_m=32, tile_n=128)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_rejects_bad_tiling(self):
        xq = jnp.zeros((30, 256), jnp.float32)  # 30 % 32 != 0
        wq = jnp.zeros((256, 128), jnp.float32)
        fs = jnp.ones((1, 128), jnp.float32)
        with pytest.raises(ValueError):
            smac_xbar(xq, wq, fs)

    @settings(max_examples=15, deadline=None)
    @given(
        m_blocks=st.integers(1, 3),
        kc=st.sampled_from([64, 128, 256]),
        chunks=st.integers(1, 3),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, m_blocks, kc, chunks, seed):
        m, k, n = 32 * m_blocks, kc * chunks, 128
        x, w = rand(seed, m, k, scale=0.5), rand(seed + 1, k, n, scale=0.05)
        out = smac_full(x, w, k_chunk=kc, tile_m=32, tile_n=128)
        want = ref.smac_float(x, w)
        rel = np.linalg.norm(out - want) / max(np.linalg.norm(want), 1e-9)
        assert rel < 0.03


# ---------------------------------------------------------------------------
# PWL softmax (SCU)
# ---------------------------------------------------------------------------

class TestSoftmaxPwl:
    def test_matches_ref_exactly(self):
        x = rand(40, 32, 64, scale=3.0)
        out = softmax_pwl(x)
        want = ref.softmax_pwl(x)
        np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-7)

    def test_close_to_true_softmax(self):
        # 8-segment chord PWL of exp has max deviation ~0.077 (midpoint of
        # the [-1,0] segment); after row normalization the softmax outputs
        # deviate by at most ~the same amount in the worst case.
        x = rand(41, 32, 64, scale=2.0)
        out = softmax_pwl(x)
        want = jax.nn.softmax(x, axis=-1)
        assert np.max(np.abs(np.asarray(out) - np.asarray(want))) < 0.08

    def test_rows_sum_to_one(self):
        x = rand(42, 64, 128, scale=5.0)
        out = softmax_pwl(x)
        np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, rtol=1e-5)

    def test_non_negative(self):
        x = rand(43, 32, 64, scale=10.0)
        assert (np.asarray(softmax_pwl(x)) >= 0).all()

    def test_pwl_exp_monotone_and_bounded(self):
        t = jnp.linspace(-10, 0, 257)
        y = np.asarray(ref.pwl_exp(t))
        assert (np.diff(y) >= -1e-7).all(), "PWL exp must be monotone"
        assert abs(y[-1] - 1.0) < 1e-6, "exp(0) segment endpoint is exact"
        true = np.exp(np.clip(np.asarray(t), -8, 0))
        # chord over [-1, 0] deviates from exp by ~0.077 at the midpoint —
        # that is the 8-segment LUT's intrinsic approximation error
        assert np.max(np.abs(y - true)) < 0.08

    @settings(max_examples=20, deadline=None)
    @given(rows=st.sampled_from([32, 64]), cols=st.sampled_from([32, 64, 128]),
           seed=st.integers(0, 2**16), scale=st.floats(0.1, 8.0))
    def test_hypothesis_sweep(self, rows, cols, seed, scale):
        x = rand(seed, rows, cols, scale=scale)
        out = softmax_pwl(x)
        want = ref.softmax_pwl(x)
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, rtol=1e-5)
