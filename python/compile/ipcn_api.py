"""IPCN firmware API + compiler (paper §II-B.5): "A toolchain consists of
an application programming interface (API) and a program compiler is
developed in Python to facilitate the hardware utilization… The compiler
converts the user program into a hex file to be loaded into the NPM."

The hex format is identical to the rust assembler's (`rust/src/isa/
program.rs::Program::to_hex`); `python/tests/test_ipcn_api.py` pins the two
against each other on golden vectors.

30-bit instruction layout (Fig 3(g)):
    [29:23] rd_en  [22:19] mode_sel  [18:12] out_en  [11:10] intxfer_en
    [9:0]   SP_addr
"""

from __future__ import annotations

import dataclasses
import enum
from typing import List, Sequence, Tuple


class Port(enum.IntEnum):
    NORTH = 0
    EAST = 1
    SOUTH = 2
    WEST = 3
    PE = 4
    UP = 5
    DOWN = 6


class Mode(enum.IntEnum):
    IDLE = 0
    ROUTE = 1
    PARTIAL_SUM = 2
    LINEAR_ACT = 3
    DMAC = 4
    SP_READ = 5
    SP_WRITE = 6
    PE_TRIGGER = 7
    DMAC_DRAIN = 8
    SCU_STREAM = 9


class IntXfer(enum.IntEnum):
    NONE = 0
    FIFO_TO_SP = 1
    SP_TO_FIFO = 2
    SWAP = 3


def port_mask(ports: Sequence[Port]) -> int:
    m = 0
    for p in ports:
        m |= 1 << int(p)
    return m


@dataclasses.dataclass(frozen=True)
class Instr:
    """One 30-bit IPCN instruction."""

    rd_en: int = 0
    mode: Mode = Mode.IDLE
    out_en: int = 0
    intxfer: IntXfer = IntXfer.NONE
    sp_addr: int = 0

    def encode(self) -> int:
        if not 0 <= self.sp_addr < 1024:
            raise ValueError(f"SP_addr overflows 10 bits: {self.sp_addr}")
        if not 0 <= self.rd_en < 128 or not 0 <= self.out_en < 128:
            raise ValueError("port mask overflows 7 bits")
        return (
            (self.rd_en << 23)
            | (int(self.mode) << 19)
            | (self.out_en << 12)
            | (int(self.intxfer) << 10)
            | self.sp_addr
        )


IDLE = Instr()

# CFR command-select encoding
SEL_IDLE, SEL_CMD1, SEL_CMD2 = 0, 1, 2


@dataclasses.dataclass
class Row:
    """One NPM row: CMD1 + CMD2 (CMR) and per-router select + repeat (CFR)."""

    cmd1: Instr
    cmd2: Instr
    sel: List[int]  # one of SEL_* per router
    repeat: int = 1


class ProgramBuilder:
    """Firmware author API over a dim×dim mesh, mirroring the rust
    `isa::Assembler` semantics (≤2 distinct commands per row)."""

    def __init__(self, dim: int):
        self.dim = dim
        self.rows: List[Row] = []

    def n_routers(self) -> int:
        return self.dim * self.dim

    def row(self, ops: Sequence[Tuple[Tuple[int, int, int, int], Instr]],
            repeat: int = 1) -> None:
        """Add one row. `ops` = [((r0, c0, r1, c1), instr), ...] — regions
        with at most two distinct instructions; regions must not overlap."""
        distinct: List[Instr] = []
        for _, instr in ops:
            if instr not in distinct:
                distinct.append(instr)
        if len(distinct) > 2:
            raise ValueError("an NPM row holds at most 2 distinct commands")
        cmd1 = distinct[0] if distinct else IDLE
        cmd2 = distinct[1] if len(distinct) > 1 else IDLE
        sel = [SEL_IDLE] * self.n_routers()
        for (r0, c0, r1, c1), instr in ops:
            if r1 >= self.dim or c1 >= self.dim:
                raise ValueError("region out of mesh bounds")
            s = SEL_CMD1 if instr == cmd1 else SEL_CMD2
            for r in range(r0, r1 + 1):
                for c in range(c0, c1 + 1):
                    idx = r * self.dim + c
                    if sel[idx] != SEL_IDLE:
                        raise ValueError("overlapping regions in one row")
                    sel[idx] = s
        self.rows.append(Row(cmd1, cmd2, sel, repeat))

    def pipeline_east(self, row: int, length: int) -> None:
        instr = Instr(rd_en=port_mask([Port.WEST]), mode=Mode.ROUTE,
                      out_en=port_mask([Port.EAST]))
        self.row([((row, 0, row, self.dim - 1), instr)], repeat=length)

    def compile_hex(self) -> str:
        """Emit the NPM hex file — byte-identical to rust `Program::to_hex`:
        per line `CMD1;CMD2;REPEAT;SEL` with 8-hex-digit words and SEL
        packed 2 bits per router, 4 routers per hex byte pair."""
        out = []
        for row in self.rows:
            sel_bytes = []
            cur = 0
            for i, s in enumerate(row.sel):
                cur |= (s & 0b11) << ((i % 4) * 2)
                if i % 4 == 3:
                    sel_bytes.append(cur)
                    cur = 0
            if self.n_routers() % 4 != 0:
                sel_bytes.append(cur)
            sel_hex = "".join(f"{b:02x}" for b in sel_bytes)
            out.append(
                f"{row.cmd1.encode():08x};{row.cmd2.encode():08x};"
                f"{row.repeat:08x};{sel_hex}"
            )
        return "\n".join(out) + ("\n" if out else "")
