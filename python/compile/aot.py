"""AOT lowering: JAX (L2, calling L1 pallas kernels) → HLO text artifacts.

HLO *text* is the interchange format, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly. Lowered with return_tuple=True; the rust side unwraps
with `to_tuple1()`.

Run once via `make artifacts`; python is never on the request path.

Artifacts (all f32):
  decoder_tiny.hlo.txt   — decoder block fwd, float path, TINY config
  attention_tiny.hlo.txt — raw flash-MHA [H,S,D] (the simulator's attention
                           oracle: the rust functional sim reproduces this)
  softmax_pwl.hlo.txt    — the SCU transfer function on a [32, 64] tile
  decoder_quant.hlo.txt  — decoder through the SMAC/PWL quantized path
  manifest.json          — shapes + param order for the rust loader
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> dict:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    shapes = [list(a.shape) for a in example_args]
    print(f"  wrote {path} ({len(text)} chars), args={shapes}")
    return {"path": os.path.basename(path), "arg_shapes": shapes}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = model.TINY
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    params = model.init_params(cfg)
    param_specs = tuple(
        jax.ShapeDtypeStruct(params[k].shape, jnp.float32) for k in model.PARAM_ORDER
    )
    x_spec = spec(cfg.seq, cfg.d_model)
    qkv_spec = spec(cfg.n_heads, cfg.seq, cfg.d_head)

    manifest = {
        "config": {
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq": cfg.seq,
        },
        "param_order": model.PARAM_ORDER,
        "artifacts": {},
    }

    print("AOT-lowering PICNIC oracle artifacts:")
    manifest["artifacts"]["decoder_tiny"] = lower_to_file(
        model.decoder_float_flat, (x_spec, *param_specs),
        os.path.join(args.out_dir, "decoder_tiny.hlo.txt"))
    manifest["artifacts"]["attention_tiny"] = lower_to_file(
        model.attention_float_flat, (qkv_spec, qkv_spec, qkv_spec),
        os.path.join(args.out_dir, "attention_tiny.hlo.txt"))
    manifest["artifacts"]["softmax_pwl"] = lower_to_file(
        model.softmax_pwl_flat, (spec(32, 64),),
        os.path.join(args.out_dir, "softmax_pwl.hlo.txt"))
    manifest["artifacts"]["decoder_quant"] = lower_to_file(
        model.decoder_quant_flat, (x_spec, *param_specs),
        os.path.join(args.out_dir, "decoder_quant.hlo.txt"))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("  wrote manifest.json")


if __name__ == "__main__":
    main()
