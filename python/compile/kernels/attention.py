"""L1 Pallas kernel: FlashAttention-style tiled attention.

PICNIC schedules attention as a two-level nested loop (paper §III.3): the
outer loop walks Q row-tiles held in the scratchpads near the W_Q region;
the inner loop streams K/V column-tiles through the IPCN DMAC macros with an
online-softmax accumulator (the SCU recurrence). On TPU-shaped hardware the
same insight maps to VMEM tiles: each grid step owns one (block_q × d) Q tile
in VMEM and scans K/V in (block_k × d) tiles — BlockSpec expresses the
HBM↔VMEM schedule that the paper expresses as DRAM↔scratchpad traffic.

interpret=True throughout: the CPU PJRT client cannot run Mosaic
custom-calls; numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                            causal: bool, sm_scale: float):
    """One grid step: one Q row-tile against all K/V column-tiles.

    Online softmax: carry (m, l, acc) across K tiles — m is the running row
    max, l the running denominator, acc the running weighted V sum. This is
    exactly the SCU streaming recurrence with the partial-sum adder folded
    into the scan.
    """
    q_tile_idx = pl.program_id(0)
    block_q = q_ref.shape[0]
    seq_k = k_ref.shape[0]
    d = q_ref.shape[1]

    q = q_ref[...].astype(jnp.float32) * sm_scale

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)

    num_k_blocks = seq_k // block_k

    def body(kb, carry):
        m_prev, l_prev, acc_prev = carry
        k_tile = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k_tile.astype(jnp.float32).T  # [block_q, block_k]
        if causal:
            q_pos = q_tile_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_cur = acc_prev * alpha[:, None] + p @ v_tile.astype(jnp.float32)
        return m_cur, l_cur, acc_cur

    m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m0, l0, acc0))
    l = jnp.maximum(l, 1e-30)  # fully-masked rows (cannot happen when causal+square)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = 32, block_k: int = 32,
                    causal: bool = True) -> jax.Array:
    """Tiled attention for a single head. q: [S_q, D], k/v: [S_k, D].

    Grid = S_q/block_q steps; each owns a Q tile in VMEM and scans K/V.
    Requires S_q % block_q == 0 and S_k % block_k == 0 (the mapper pads).
    """
    seq_q, d = q.shape
    seq_k = k.shape[0]
    if seq_q % block_q or seq_k % block_k:
        raise ValueError(f"shape ({seq_q},{seq_k}) not divisible by blocks "
                         f"({block_q},{block_k})")
    sm_scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _flash_attention_kernel, block_k=block_k, causal=causal, sm_scale=sm_scale
    )
    return pl.pallas_call(
        kernel,
        grid=(seq_q // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((seq_k, d), lambda i: (0, 0)),
            pl.BlockSpec((seq_k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((seq_q, d), q.dtype),
        interpret=True,
    )(q, k, v)


def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              block_q: int = 32, block_k: int = 32,
              causal: bool = True) -> jax.Array:
    """Multi-head wrapper: [H, S, D]."""
    f = functools.partial(flash_attention, block_q=block_q, block_k=block_k,
                          causal=causal)
    return jax.vmap(f)(q, k, v)
