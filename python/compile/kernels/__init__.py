"""PICNIC L1 kernels (Pallas, interpret=True) and their pure-jnp oracles."""

from . import attention, ref, smac, softmax_pwl  # noqa: F401
