"""L1 Pallas kernel: SCU softmax with 8-segment piecewise-linear exp.

The Softmax Compute Unit (paper §II-C, Fig 4) is a 3-state FSM:
  state 1 — stream inputs, compute PWL exp of (x - max), accumulate the
            partial sum and fill the indexed cache;
  state 2 — reciprocal of the partial sum;
  state 3 — multiply cache entries by the reciprocal, stream out.

As a Pallas kernel the "indexed cache" is the VMEM row tile and the FSM
collapses into a row-wise reduce + scale; the PWL LUT (8 slope/intercept
pairs) is passed in as tiny operands so the same tables drive the rust SCU
model (rust/src/scu/) — single source of truth for the approximation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PWL_HI, PWL_LO, PWL_SEGMENTS


def _softmax_pwl_kernel(x_ref, slope_ref, icept_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    slope = slope_ref[...]
    icept = icept_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    t = jnp.clip(x - m, PWL_LO, PWL_HI)
    width = (PWL_HI - PWL_LO) / PWL_SEGMENTS
    seg = jnp.clip(jnp.floor((t - PWL_LO) / width).astype(jnp.int32),
                   0, PWL_SEGMENTS - 1)
    e = slope[seg] * t + icept[seg]
    denom = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e / denom).astype(o_ref.dtype)


def softmax_pwl(x: jax.Array, *, block_rows: int = 32) -> jax.Array:
    """Row-wise PWL softmax over the last axis of a 2-D array [R, C]."""
    from .ref import PWL_INTERCEPT, PWL_SLOPE

    r, c = x.shape
    if r % block_rows:
        raise ValueError(f"rows {r} not divisible by block_rows {block_rows}")
    return pl.pallas_call(
        _softmax_pwl_kernel,
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((PWL_SEGMENTS,), lambda i: (0,)),
            pl.BlockSpec((PWL_SEGMENTS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), x.dtype),
        interpret=True,
    )(x, PWL_SLOPE, PWL_INTERCEPT)
