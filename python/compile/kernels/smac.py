"""L1 Pallas kernel: quantized crossbar SMAC (static-weight MAC).

Emulates the RRAM-CIM PE (paper §II-A): weights live as conductance levels
in a 256×256 crossbar; inputs are DAC-quantized, the analog bitline sum is
ADC-quantized with a calibrated per-column full-scale, then dequantized.

Hardware adaptation (DESIGN.md §5): the 256×256 analog crossbar is expressed
as an MXU-shaped tile matmul with the ADC transfer function fused into the
epilogue — one grid step per (tile_m × tile_n) output tile, scanning K in
crossbar-row-sized chunks, which is exactly how the mapper splits a weight
matrix across PEs along the reduction dimension.

The kernel takes *pre-quantized* integer codes (as f32, exact up to 2^24) and
the calibration scales — quantization itself is a programming-time step
performed once per model, matching the paper's one-shot RRAM programming.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _smac_kernel(xq_ref, wq_ref, fs_ref, o_ref, *, adc_bits: int, k_chunk: int):
    """One output tile: integer MAC over K chunks, then per-chunk ADC.

    The ADC is applied per K-chunk of size `k_chunk` (one physical crossbar's
    worth of rows): each crossbar column converts its own analog sum before
    the digital partial-sum reduction in the IPCN routers — this ordering is
    what makes the PE/IPCN split visible in the numerics.
    """
    k_total = xq_ref.shape[1]
    num_chunks = k_total // k_chunk
    adc_max = float(2 ** (adc_bits - 1) - 1)

    acc0 = jnp.zeros((xq_ref.shape[0], o_ref.shape[1]), jnp.float32)

    def body(c, acc):
        x_chunk = pl.load(xq_ref, (slice(None), pl.dslice(c * k_chunk, k_chunk)))
        w_chunk = pl.load(wq_ref, (pl.dslice(c * k_chunk, k_chunk), slice(None)))
        analog = x_chunk @ w_chunk  # bitline accumulation (exact int in f32)
        # ADC: per-column full-scale from calibration, round + clip to swing.
        fs = pl.load(fs_ref, (pl.dslice(c, 1), slice(None)))[0]
        lsb = fs / adc_max
        digital = jnp.clip(jnp.round(analog / lsb[None, :]), -adc_max, adc_max)
        return acc + digital * lsb[None, :]

    o_ref[...] = jax.lax.fori_loop(0, num_chunks, body, acc0).astype(o_ref.dtype)


def smac_xbar(xq: jax.Array, wq: jax.Array, full_scale: jax.Array, *,
              adc_bits: int = 12, k_chunk: int = 256,
              tile_m: int = 32, tile_n: int = 128) -> jax.Array:
    """Crossbar matmul on integer codes. xq: [M, K] f32 int codes,
    wq: [K, N] f32 conductance codes, full_scale: [K/k_chunk, N] per-chunk
    per-column ADC full-scale. Returns dequantized-in-code-space [M, N]
    (caller multiplies by DAC/weight scales).
    """
    m, k = xq.shape
    _, n = wq.shape
    if k % k_chunk or m % tile_m or n % tile_n:
        raise ValueError(f"({m},{k},{n}) not divisible by tiles "
                         f"({tile_m},{k_chunk},{tile_n})")
    kernel = functools.partial(_smac_kernel, adc_bits=adc_bits, k_chunk=k_chunk)
    return pl.pallas_call(
        kernel,
        grid=(m // tile_m, n // tile_n),
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_n), lambda i, j: (0, j)),
            pl.BlockSpec((k // k_chunk, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(xq, wq, full_scale)


def calibrate_full_scale(xq: jax.Array, wq: jax.Array, *, k_chunk: int = 256) -> jax.Array:
    """Feedback-loop calibration (paper §II-A): run the calibration set
    through each crossbar chunk and record the max |column sum| as the ADC
    full-scale, so the input swing is fully utilized."""
    k = xq.shape[1]
    chunks = []
    for c in range(k // k_chunk):
        x_c = xq[:, c * k_chunk:(c + 1) * k_chunk]
        w_c = wq[c * k_chunk:(c + 1) * k_chunk, :]
        chunks.append(jnp.maximum(jnp.max(jnp.abs(x_c @ w_c), axis=0), 1.0))
    return jnp.stack(chunks, axis=0)


def smac_full(x: jax.Array, w: jax.Array, *, w_levels: int = 256, x_bits: int = 8,
              adc_bits: int = 12, k_chunk: int = 256,
              tile_m: int = 32, tile_n: int = 128) -> jax.Array:
    """End-to-end SMAC: quantize → crossbar kernel → dequantize.

    Matches kernels.ref.smac when k_chunk >= K (single crossbar) and the
    calibration set equals the eval set; otherwise it is the *more faithful*
    model (per-crossbar ADC before digital reduction).
    """
    from . import ref

    wq, ws = ref.quantize_weights(w, w_levels)
    xq, xs = ref.quantize_inputs(x, x_bits)
    xq = xq.astype(jnp.float32)
    wq = wq.astype(jnp.float32)
    fs = calibrate_full_scale(xq, wq, k_chunk=k_chunk)
    acc = smac_xbar(xq, wq, fs, adc_bits=adc_bits, k_chunk=k_chunk,
                    tile_m=tile_m, tile_n=tile_n)
    return acc * xs[..., None] * ws[None, :]
