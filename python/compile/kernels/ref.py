"""Pure-jnp reference oracles for the PICNIC L1 kernels.

Every Pallas kernel in this package has an exact (or bounded-error) reference
here, written with plain jax.numpy only — no pallas, no custom calls. The
pytest suite asserts kernel-vs-ref allclose; these functions are therefore
the ground truth for the whole stack (the rust simulator is in turn checked
against the AOT-lowered L2 model, which calls the kernels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """Standard softmax attention. q,k,v: [S, D] (single head).

    Matches the PICNIC dataflow: S = QK^T / sqrt(D), row-softmax, SV.
    """
    s, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[0]), dtype=bool), k=k.shape[0] - s)
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, q.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    return p @ v


def mha(q, k, v, *, causal: bool = True):
    """Multi-head attention over [H, S, D] tensors."""
    return jax.vmap(lambda qh, kh, vh: attention(qh, kh, vh, causal=causal))(q, k, v)


# ---------------------------------------------------------------------------
# Quantized crossbar SMAC (static-weight MAC on the RRAM-CIM PE)
# ---------------------------------------------------------------------------


def quantize_weights(w: jax.Array, levels: int = 256):
    """Symmetric per-column quantization of a weight matrix to RRAM
    conductance levels. Returns (codes int32 in [-(L/2-1), L/2-1], scale)."""
    qmax = levels // 2 - 1
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8) / qmax
    codes = jnp.clip(jnp.round(w / scale[None, :]), -qmax, qmax).astype(jnp.int32)
    return codes, scale


def quantize_inputs(x: jax.Array, bits: int = 8):
    """DAC quantization of the input activations (per-row symmetric)."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-8) / qmax
    codes = jnp.clip(jnp.round(x / scale[..., None]), -qmax, qmax).astype(jnp.int32)
    return codes, scale


def smac(x: jax.Array, w: jax.Array, *, w_levels: int = 256, x_bits: int = 8,
         adc_bits: int = 12) -> jax.Array:
    """Reference for the crossbar SMAC: quantize inputs and weights, integer
    MAC down the bitlines, ADC re-quantization of the analog column sum, then
    dequantize. x: [M, K], w: [K, N] -> [M, N].

    The ADC clips/quantizes the *column sum* to `adc_bits` — this is the
    dominant non-ideality the paper's feedback-loop calibration targets: the
    calibration picks the per-column full-scale so the ADC swing is fully
    used, minimizing discretization error.
    """
    wq, ws = quantize_weights(w, w_levels)
    xq, xs = quantize_inputs(x, x_bits)
    acc = xq.astype(jnp.float32) @ wq.astype(jnp.float32)  # exact int MAC
    # Feedback-loop calibration: per-column full-scale = observed max |sum|.
    full_scale = jnp.maximum(jnp.max(jnp.abs(acc), axis=0), 1.0)
    adc_max = 2 ** (adc_bits - 1) - 1
    lsb = full_scale / adc_max
    acc_adc = jnp.clip(jnp.round(acc / lsb[None, :]), -adc_max, adc_max) * lsb[None, :]
    return acc_adc * xs[..., None] * ws[None, :]


def smac_float(x: jax.Array, w: jax.Array) -> jax.Array:
    """Ideal float matmul — the asymptote smac() must approach as bits grow."""
    return x @ w


# ---------------------------------------------------------------------------
# Piecewise-linear softmax (SCU)
# ---------------------------------------------------------------------------

# 8-segment PWL approximation of exp(t) on t in [-8, 0] (softmax operates on
# max-shifted scores, so the domain is non-positive). Segment i covers
# [-8 + i, -7 + i). Slopes/intercepts from the chord between segment ends —
# matches an 8-entry hardware LUT; max abs error ~2e-2 near t=0.
PWL_SEGMENTS = 8
PWL_LO = -8.0
PWL_HI = 0.0


def _pwl_tables():
    import numpy as np

    edges = np.linspace(PWL_LO, PWL_HI, PWL_SEGMENTS + 1)
    x0, x1 = edges[:-1], edges[1:]
    y0, y1 = np.exp(x0), np.exp(x1)
    slope = (y1 - y0) / (x1 - x0)
    intercept = y0 - slope * x0
    return (
        jnp.asarray(slope, jnp.float32),
        jnp.asarray(intercept, jnp.float32),
        jnp.asarray(edges, jnp.float32),
    )


PWL_SLOPE, PWL_INTERCEPT, PWL_EDGES = _pwl_tables()


def pwl_exp(t: jax.Array) -> jax.Array:
    """8-segment PWL exp for t <= 0; values below -8 clamp to the last chord."""
    tc = jnp.clip(t, PWL_LO, PWL_HI)
    seg = jnp.clip(
        jnp.floor((tc - PWL_LO) / ((PWL_HI - PWL_LO) / PWL_SEGMENTS)).astype(jnp.int32),
        0,
        PWL_SEGMENTS - 1,
    )
    return PWL_SLOPE[seg] * tc + PWL_INTERCEPT[seg]


def softmax_pwl(x: jax.Array, axis: int = -1) -> jax.Array:
    """SCU reference: max-shift, PWL exp, sum, reciprocal, scale.

    Mirrors the 3-state SCU FSM: state 1 streams exp(x_i - max) into the
    indexed cache and partial-sum adder; state 2 computes the reciprocal of
    the partial sum; state 3 multiplies cache entries by it.
    """
    m = jnp.max(x, axis=axis, keepdims=True)
    e = pwl_exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# Transformer blocks (used by the L2 model and its tests)
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """Llama-style SwiGLU feed-forward."""
    return (silu(x @ w_gate) * (x @ w_up)) @ w_down
