"""L2 JAX model: llama-style decoder block forward, calling the L1 kernels.

This is the functional golden model of what the PICNIC chiplet executes:
one decoder = attention layer (QKV/O projections on RRAM SMAC, attention on
the IPCN DMACs + SCU) + SwiGLU feed-forward (three more SMAC matmuls). The
rust simulator computes the same math through its cycle-level PE/router
models; integration tests compare its outputs against this module, executed
via the AOT HLO on the PJRT runtime.

Two fidelity variants per entry point:
  * `*_float`  — exact float math through the pallas flash-attention kernel
                 (bit-comparable oracle for the mapper's dataflow);
  * `*_quant`  — SMAC-quantized projections + PWL softmax (the accelerator's
                 actual transfer function, for accuracy-bound tests).

Everything here is build-time only; `aot.py` lowers it once to HLO text.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.attention import flash_mha
from .kernels.smac import smac_full
from .kernels.softmax_pwl import softmax_pwl


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dimensions of one decoder. Defaults = the tiny test config; real Llama
    configs live in rust/src/models/ (the simulator side) — the oracle only
    needs a representative block, not 8B parameters."""

    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 128
    seq: int = 64

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


TINY = ModelConfig()


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Seeded synthetic weights at true block dimensions (DESIGN.md §4:
    timing/energy depend on dims, numerics are validated on this config)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    s = 0.02
    return {
        "wq": s * jax.random.normal(ks[0], (cfg.d_model, cfg.d_model), jnp.float32),
        "wk": s * jax.random.normal(ks[1], (cfg.d_model, cfg.d_model), jnp.float32),
        "wv": s * jax.random.normal(ks[2], (cfg.d_model, cfg.d_model), jnp.float32),
        "wo": s * jax.random.normal(ks[3], (cfg.d_model, cfg.d_model), jnp.float32),
        "w_gate": s * jax.random.normal(ks[4], (cfg.d_model, cfg.d_ff), jnp.float32),
        "w_up": s * jax.random.normal(ks[5], (cfg.d_model, cfg.d_ff), jnp.float32),
        "w_down": s * jax.random.normal(ks[6], (cfg.d_ff, cfg.d_model), jnp.float32),
        "g_attn": jnp.ones((cfg.d_model,), jnp.float32),
        "g_ffn": jnp.ones((cfg.d_model,), jnp.float32),
    }


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    s, d = x.shape
    return x.reshape(s, n_heads, d // n_heads).transpose(1, 0, 2)


def _merge_heads(x: jax.Array) -> jax.Array:
    h, s, d = x.shape
    return x.transpose(1, 0, 2).reshape(s, h * d)


def attention_block_float(x: jax.Array, p: Dict[str, jax.Array],
                          cfg: ModelConfig) -> jax.Array:
    """Attention sub-layer, float path, flash-attention pallas kernel."""
    h = ref.rmsnorm(x, p["g_attn"])
    q = _split_heads(h @ p["wq"], cfg.n_heads)
    k = _split_heads(h @ p["wk"], cfg.n_heads)
    v = _split_heads(h @ p["wv"], cfg.n_heads)
    o = _merge_heads(flash_mha(q, k, v, block_q=32, block_k=32, causal=True))
    return x + o @ p["wo"]


def attention_block_quant(x: jax.Array, p: Dict[str, jax.Array],
                          cfg: ModelConfig, *, adc_bits: int = 12) -> jax.Array:
    """Attention sub-layer through the accelerator's transfer function:
    SMAC-quantized projections, exact QK^T/SV on the DMACs (digital), PWL
    softmax on the SCU."""
    h = ref.rmsnorm(x, p["g_attn"])
    kc = min(256, cfg.d_model)
    mm = lambda a, w: smac_full(a, w, adc_bits=adc_bits, k_chunk=kc,
                                tile_m=32, tile_n=min(128, w.shape[1]))
    q = _split_heads(mm(h, p["wq"]), cfg.n_heads)
    k = _split_heads(mm(h, p["wk"]), cfg.n_heads)
    v = _split_heads(mm(h, p["wv"]), cfg.n_heads)

    def head(qh, kh, vh):
        s = qh @ kh.T / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
        mask = jnp.tril(jnp.ones((cfg.seq, cfg.seq), dtype=bool))
        s = jnp.where(mask, s, -1e30)
        pmat = softmax_pwl(s, block_rows=32)
        return pmat @ vh

    o = _merge_heads(jax.vmap(head)(q, k, v))
    return x + mm(o, p["wo"])


def ffn_block(x: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    h = ref.rmsnorm(x, p["g_ffn"])
    return x + ref.ffn(h, p["w_gate"], p["w_up"], p["w_down"])


def decoder_block_float(x: jax.Array, p: Dict[str, jax.Array],
                        cfg: ModelConfig) -> jax.Array:
    """Full decoder: attention + FFN, float path. The primary AOT artifact."""
    return ffn_block(attention_block_float(x, p, cfg), p)


def decoder_block_quant(x: jax.Array, p: Dict[str, jax.Array],
                        cfg: ModelConfig) -> jax.Array:
    return ffn_block(attention_block_quant(x, p, cfg), p)


# --- flat-argument wrappers for AOT lowering (stable positional signature) --

PARAM_ORDER = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
               "g_attn", "g_ffn"]


def _pack(p: Dict[str, jax.Array]):
    return tuple(p[k] for k in PARAM_ORDER)


def _unpack(args) -> Dict[str, jax.Array]:
    return dict(zip(PARAM_ORDER, args))


def decoder_float_flat(x, *params):
    return (decoder_block_float(x, _unpack(params), TINY),)


def decoder_quant_flat(x, *params):
    return (decoder_block_quant(x, _unpack(params), TINY),)


def attention_float_flat(q, k, v):
    """Raw MHA for the oracle of the simulator's attention dataflow:
    q,k,v already projected, [H, S, D]."""
    return (flash_mha(q, k, v, block_q=32, block_k=32, causal=True),)


def softmax_pwl_flat(x):
    return (softmax_pwl(x, block_rows=32),)
